//! Continuous-time replay of slotted schedules.
//!
//! The solvers decide in integer slots (§III's time-slotted model); a real
//! deployment executes the same *decisions* (assignment, per-helper
//! processing order, preemption points) with the true millisecond
//! durations. Because slot counts are ceilings, the slotted makespan
//! overestimates the realized one — exactly the effect the paper discusses
//! around Fig. 6 ("the helper will need a bit less than 3 slots … it may
//! be able to start processing the next task before the end of the 3rd
//! slot"). This engine measures the realized makespan.
//!
//! Mechanics: each client's fwd/bwd run set already *is* the maximal
//! contiguous segment list (the [`SlotRuns`](crate::solver::schedule::SlotRuns)
//! representation); a segment of k slots out of the task's n total
//! carries k/n of the task's true processing time. Per helper, segments
//! execute in slot order ([`super::segments::streams`], shared with the
//! epoch engine); a segment may start only when the previous segment on
//! that helper finished AND its task is ready (fwd: after r_ms; bwd:
//! after the client-side turnaround l_ms + l'_ms following fwd
//! completion). Completion of client j = bwd finish + r'_ms.

use super::segments;
use crate::instance::InstanceMs;
use crate::solver::schedule::Schedule;
use crate::util::rng::Rng;

/// Result of one replay.
#[derive(Clone, Debug)]
pub struct Replay {
    /// Realized batch makespan in ms.
    pub makespan_ms: f64,
    /// Per-client completion times (ms).
    pub completion_ms: Vec<f64>,
    /// Per-helper busy time (ms).
    pub helper_busy_ms: Vec<f64>,
    /// Per-helper utilization = busy / makespan.
    pub helper_util: Vec<f64>,
    /// Per-client queuing delay (ms): completion − ideal unqueued path.
    pub queuing_ms: Vec<f64>,
}

/// Replay `schedule` against the continuous instance. `jitter` optionally
/// multiplies every true duration by lognormal(1, σ) noise (failure/jitter
/// injection for robustness experiments); pass `None` for deterministic
/// replay.
pub fn replay(inst: &InstanceMs, schedule: &Schedule, mut jitter: Option<(&mut Rng, f64)>) -> Replay {
    let jn = inst.n_clients;
    let mut completion = vec![0.0f64; jn];
    let mut queuing = vec![0.0f64; jn];
    let mut busy = vec![0.0f64; inst.n_helpers];
    let mut makespan: f64 = 0.0;

    let mut jit = |x: f64| -> f64 {
        match &mut jitter {
            Some((rng, sigma)) => rng.lognormal_median(x, *sigma),
            None => x,
        }
    };

    let members = schedule.assignment.members_by_helper(inst.n_helpers);
    let streams = segments::streams(inst.n_helpers, schedule);
    // Per-client slot in the per-helper state vectors (rebuilt per helper).
    let mut k_of = vec![usize::MAX; jn];
    for i in 0..inst.n_helpers {
        let clients = &members[i];
        if clients.is_empty() {
            continue;
        }
        for (k, &j) in clients.iter().enumerate() {
            k_of[j] = k;
        }

        // True durations (possibly jittered once per task, split by frac).
        let true_ms: Vec<(f64, f64)> = clients
            .iter()
            .map(|&j| {
                let e = inst.edge(i, j);
                (jit(inst.p_ms[e]), jit(inst.pp_ms[e]))
            })
            .collect();

        // Execute.
        let mut clock = 0.0f64;
        let mut fwd_done = vec![0.0f64; clients.len()];
        let mut fwd_rem: Vec<f64> = true_ms.iter().map(|t| t.0).collect();
        let mut bwd_rem: Vec<f64> = true_ms.iter().map(|t| t.1).collect();
        for seg in &streams[i] {
            let k = k_of[seg.client];
            let e = inst.edge(i, seg.client);
            let ready = if seg.is_bwd {
                fwd_done[k] + inst.l_ms[e] + inst.lp_ms[e]
            } else {
                inst.r_ms[e]
            };
            let start = clock.max(ready);
            let dur = if seg.is_bwd { true_ms[k].1 * seg.frac } else { true_ms[k].0 * seg.frac };
            clock = start + dur;
            busy[i] += dur;
            if seg.is_bwd {
                bwd_rem[k] -= dur;
                if bwd_rem[k] <= 1e-9 {
                    let fin = clock + inst.rp_ms[e];
                    completion[seg.client] = fin;
                    let ideal = inst.r_ms[e]
                        + inst.p_ms[e]
                        + inst.l_ms[e]
                        + inst.lp_ms[e]
                        + inst.pp_ms[e]
                        + inst.rp_ms[e];
                    queuing[seg.client] = (fin - ideal).max(0.0);
                    makespan = makespan.max(fin);
                }
            } else {
                fwd_rem[k] -= dur;
                if fwd_rem[k] <= 1e-9 {
                    fwd_done[k] = clock;
                }
            }
        }
    }
    let util = busy.iter().map(|&b| if makespan > 0.0 { b / makespan } else { 0.0 }).collect();
    Replay { makespan_ms: makespan, completion_ms: completion, helper_busy_ms: busy, helper_util: util, queuing_ms: queuing }
}

/// [`replay`] under a transport model: transfer phases (r, l, l', r') are
/// resolved through the same contention projection the solver scheduled
/// against ([`crate::transport::TransportCfg::inflate_ms_for_assignment`]), so simulator
/// and solver can never disagree about effective rates. Dedicated mode
/// delegates directly — bitwise-identical to [`replay`].
pub fn replay_under(
    inst: &InstanceMs,
    schedule: &Schedule,
    transport: &crate::transport::TransportCfg,
    jitter: Option<(&mut Rng, f64)>,
) -> Replay {
    if transport.is_dedicated() {
        return replay(inst, schedule, jitter);
    }
    let eff = transport.inflate_ms_for_assignment(inst, &schedule.assignment);
    replay(&eff, schedule, jitter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::solver::{admm, greedy};
    use crate::util::prop;

    fn setup(seed: u64) -> (InstanceMs, crate::instance::Instance) {
        let ms = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 3, seed).generate();
        let slotted = ms.quantize(180.0);
        (ms, slotted)
    }

    #[test]
    fn replay_close_to_slotted_makespan() {
        // Realized makespan must be ≤ slotted-nominal (ceil effects only
        // ever overestimate) and within a slot-per-task of it.
        prop::check(10, |rng| {
            let (ms, inst) = setup(rng.next_u64());
            let s = greedy::solve(&inst).unwrap();
            let rep = replay(&ms, &s, None);
            let nominal = s.makespan(&inst) as f64 * inst.slot_ms;
            prop::assert_prop(rep.makespan_ms > 0.0, "positive makespan");
            prop::assert_prop(
                rep.makespan_ms <= nominal + 1e-6,
                &format!("realized {} > nominal {nominal}", rep.makespan_ms),
            );
            // Not absurdly smaller either (same ordering, same work).
            prop::assert_prop(
                rep.makespan_ms >= nominal * 0.3,
                &format!("realized {} too far below nominal {nominal}", rep.makespan_ms),
            );
        });
    }

    #[test]
    fn all_clients_complete() {
        let (ms, inst) = setup(4);
        let s = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap().schedule;
        let rep = replay(&ms, &s, None);
        for j in 0..ms.n_clients {
            assert!(rep.completion_ms[j] > 0.0, "client {j} never completed");
        }
        assert!((rep.makespan_ms
            - rep.completion_ms.iter().cloned().fold(0.0, f64::max))
        .abs()
            < 1e-9);
    }

    #[test]
    fn utilization_in_unit_range() {
        let (ms, inst) = setup(9);
        let s = greedy::solve(&inst).unwrap();
        let rep = replay(&ms, &s, None);
        for &u in &rep.helper_util {
            assert!((0.0..=1.0 + 1e-9).contains(&u), "util {u}");
        }
    }

    #[test]
    fn jitter_replay_is_deterministic_given_seed() {
        let (ms, inst) = setup(12);
        let s = greedy::solve(&inst).unwrap();
        let mut r1 = crate::util::rng::Rng::seeded(5);
        let mut r2 = crate::util::rng::Rng::seeded(5);
        let a = replay(&ms, &s, Some((&mut r1, 0.2)));
        let b = replay(&ms, &s, Some((&mut r2, 0.2)));
        assert_eq!(a.makespan_ms, b.makespan_ms);
        let mut r3 = crate::util::rng::Rng::seeded(6);
        let c = replay(&ms, &s, Some((&mut r3, 0.2)));
        assert_ne!(a.makespan_ms, c.makespan_ms);
    }

    #[test]
    fn queuing_delays_nonnegative() {
        let (ms, inst) = setup(15);
        let s = greedy::solve(&inst).unwrap();
        let rep = replay(&ms, &s, None);
        assert!(rep.queuing_ms.iter().all(|&q| q >= 0.0));
    }
}
