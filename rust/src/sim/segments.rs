//! The one place schedule runs become executable segment streams.
//!
//! Both replay engines ([`crate::sim::engine`] single-batch and
//! [`crate::sim::epoch`] pipelined) execute the same object: per helper,
//! the time-ordered stream of contiguous task segments, each carrying the
//! fraction of its task's true duration. Before the run-length refactor
//! each engine re-derived segments slot-by-slot from dense lists; now the
//! schedule *is* the segment list ([`SlotRuns`]), and this module is the
//! single shared projection onto per-helper streams.

use crate::solver::schedule::{Schedule, SlotRuns};

/// One executable segment of a task on its helper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskSeg {
    pub client: usize,
    pub is_bwd: bool,
    /// First slot of the contiguous run (ordering key within the helper).
    pub start: u32,
    /// Slots in the run.
    pub len: u32,
    /// Fraction of the task's true duration carried by this segment
    /// (len / total task slots).
    pub frac: f64,
}

fn push_task(stream: &mut Vec<TaskSeg>, client: usize, is_bwd: bool, runs: &SlotRuns) {
    let total = runs.len();
    if total == 0 {
        return;
    }
    for &(start, len) in runs.runs() {
        stream.push(TaskSeg { client, is_bwd, start, len, frac: len as f64 / total as f64 });
    }
}

/// Per-helper segment streams in execution order (slot order; ties broken
/// by client id then phase for determinism on degenerate schedules).
/// O(#runs log #runs) — independent of slot counts.
pub fn streams(inst_helpers: usize, schedule: &Schedule) -> Vec<Vec<TaskSeg>> {
    let mut out: Vec<Vec<TaskSeg>> = vec![Vec::new(); inst_helpers];
    for j in 0..schedule.fwd.len() {
        let i = schedule.assignment.helper_of[j];
        push_task(&mut out[i], j, false, &schedule.fwd[j]);
        push_task(&mut out[i], j, true, &schedule.bwd[j]);
    }
    for s in out.iter_mut() {
        s.sort_by_key(|seg| (seg.start, seg.client, seg.is_bwd));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::schedule::{Assignment, Schedule};

    #[test]
    fn fracs_sum_to_one_per_task_and_order_is_by_start() {
        let s = Schedule {
            assignment: Assignment::new(vec![0, 0]),
            fwd: vec![SlotRuns::from_slots(&[0, 1, 4]), SlotRuns::from_slots(&[2, 3])],
            bwd: vec![SlotRuns::from_slots(&[6]), SlotRuns::from_slots(&[7, 8])],
        };
        let st = streams(1, &s);
        assert_eq!(st.len(), 1);
        let stream = &st[0];
        // client 0 fwd splits into 2 segments (slots 0-1 and 4).
        let c0_fwd: Vec<&TaskSeg> = stream.iter().filter(|x| x.client == 0 && !x.is_bwd).collect();
        assert_eq!(c0_fwd.len(), 2);
        assert!((c0_fwd.iter().map(|x| x.frac).sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((c0_fwd[0].frac - 2.0 / 3.0).abs() < 1e-12);
        // Stream sorted by start.
        assert!(stream.windows(2).all(|w| w[0].start <= w[1].start));
        // Empty tasks produce no segments.
        let empty = Schedule {
            assignment: Assignment::new(vec![0]),
            fwd: vec![SlotRuns::new()],
            bwd: vec![SlotRuns::new()],
        };
        assert!(streams(1, &empty)[0].is_empty());
    }
}
