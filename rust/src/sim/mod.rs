//! Discrete-event simulation of the parallel-SL batch workflow:
//! continuous-time replay of slotted schedules ([`engine`]), slot-length
//! sweeps for the Fig-6 experiment ([`quantize`]) and schedule metrics /
//! Gantt export ([`metrics`]).

pub mod engine;
pub mod epoch;
pub mod metrics;
pub mod quantize;

pub use engine::{replay, Replay};
pub use metrics::{gantt_json, summarize, ScheduleMetrics};
