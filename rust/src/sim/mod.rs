//! Discrete-event simulation of the parallel-SL batch workflow:
//! continuous-time replay of slotted schedules ([`engine`]), epoch-level
//! pipelined replay ([`epoch`]), slot-length sweeps for the Fig-6
//! experiment ([`quantize`]) and schedule metrics / Gantt export
//! ([`metrics`]).
//!
//! Both replay engines execute the same object: per-helper streams of
//! contiguous task segments, projected once from the run-length-encoded
//! schedule by [`segments::streams`] — O(#preemption runs), never
//! O(total slots). The `psl perf` harness ([`crate::bench::perf`]) times
//! these paths against a dense-representation baseline to keep the
//! speedup on the record.

pub mod engine;
pub mod epoch;
pub mod metrics;
pub mod quantize;
pub mod segments;

pub use engine::{replay, replay_under, Replay};
pub use metrics::{gantt_json, summarize, ScheduleMetrics};
pub use segments::{streams, TaskSeg};
