//! Epoch-level pipelined simulation.
//!
//! The paper optimizes a *single batch*'s makespan and argues (§III
//! "Epochs & Aggregation") that the training process repeats it hundreds
//! of times. Batches of the same client are serialized by the model-
//! weight dependency, but a helper may start client A's batch k+1 fwd
//! while client B is still in batch k — the steady state *pipelines*
//! across batch boundaries. This module measures that steady state: the
//! per-batch *period* of the pipelined schedule vs the single-batch
//! makespan (period ≤ makespan; the gap is the pipelining win).

use super::segments;
use crate::instance::InstanceMs;
use crate::solver::schedule::Schedule;

/// Result of an epoch simulation.
#[derive(Clone, Debug)]
pub struct EpochReplay {
    /// Completion time (ms) of the whole epoch.
    pub epoch_ms: f64,
    /// Single-batch realized makespan (ms), for reference.
    pub batch_ms: f64,
    /// Steady-state per-batch period (ms): (epoch - first batch) / (B-1).
    pub period_ms: f64,
}

/// Replay `batches` consecutive batch updates: each helper repeats its
/// segment stream; client j's batch b tasks release only after its batch
/// b-1 completed (weight dependency) plus its client-side phases.
pub fn replay_epoch(inst: &InstanceMs, schedule: &Schedule, batches: usize) -> EpochReplay {
    assert!(batches >= 1);
    let jn = inst.n_clients;
    // Per-helper ordered segment streams — the same shared projection the
    // single-batch engine uses ([`segments::streams`]).
    let streams = segments::streams(inst.n_helpers, schedule);

    // State carried across batches.
    let mut batch_done = vec![0.0f64; jn]; // completion of client j's last batch
    let mut first_batch_ms = 0.0;
    let mut epoch_ms: f64 = 0.0;
    let mut helper_clock = vec![0.0f64; inst.n_helpers];
    for b in 0..batches {
        let mut fwd_done = vec![0.0f64; jn];
        let mut fwd_rem: Vec<f64> = (0..jn)
            .map(|j| inst.p_ms[inst.edge(schedule.assignment.helper_of[j], j)])
            .collect();
        let mut bwd_rem: Vec<f64> = (0..jn)
            .map(|j| inst.pp_ms[inst.edge(schedule.assignment.helper_of[j], j)])
            .collect();
        let mut batch_max = 0.0f64;
        for i in 0..inst.n_helpers {
            for seg in &streams[i] {
                let j = seg.client;
                let e = inst.edge(i, j);
                // Release: client-side phases chained after its previous
                // batch completion (weight dependency).
                let ready = if seg.is_bwd {
                    fwd_done[j] + inst.l_ms[e] + inst.lp_ms[e]
                } else {
                    batch_done[j] + inst.r_ms[e]
                };
                let start = helper_clock[i].max(ready);
                let dur = if seg.is_bwd { bwd_rem[j].min(inst.pp_ms[e] * seg.frac) } else { fwd_rem[j].min(inst.p_ms[e] * seg.frac) };
                helper_clock[i] = start + dur;
                if seg.is_bwd {
                    bwd_rem[j] -= dur;
                    if bwd_rem[j] <= 1e-9 {
                        let fin = helper_clock[i] + inst.rp_ms[e];
                        batch_done[j] = fin;
                        batch_max = batch_max.max(fin);
                    }
                } else {
                    fwd_rem[j] -= dur;
                    if fwd_rem[j] <= 1e-9 {
                        fwd_done[j] = helper_clock[i];
                    }
                }
            }
        }
        if b == 0 {
            first_batch_ms = batch_max;
        }
        epoch_ms = epoch_ms.max(batch_max);
    }
    let period = if batches > 1 { (epoch_ms - first_batch_ms) / (batches - 1) as f64 } else { first_batch_ms };
    EpochReplay { epoch_ms, batch_ms: first_batch_ms, period_ms: period }
}

/// [`replay_epoch`] under a transport model: the same contention
/// projection the solver and the single-batch engine use
/// ([`crate::transport::TransportCfg::inflate_ms_for_assignment`]); dedicated mode
/// delegates directly (bitwise-identical).
pub fn replay_epoch_under(
    inst: &InstanceMs,
    schedule: &Schedule,
    batches: usize,
    transport: &crate::transport::TransportCfg,
) -> EpochReplay {
    if transport.is_dedicated() {
        return replay_epoch(inst, schedule, batches);
    }
    let eff = transport.inflate_ms_for_assignment(inst, &schedule.assignment);
    replay_epoch(&eff, schedule, batches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::solver::{admm, greedy};

    fn setup(seed: u64) -> (InstanceMs, crate::instance::Instance) {
        let ms = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, seed).generate();
        let inst = ms.quantize(180.0);
        (ms, inst)
    }

    #[test]
    fn single_batch_matches_engine() {
        let (ms, inst) = setup(3);
        let s = greedy::solve(&inst).unwrap();
        let e = replay_epoch(&ms, &s, 1);
        let single = crate::sim::replay(&ms, &s, None);
        assert!((e.batch_ms - single.makespan_ms).abs() / single.makespan_ms < 0.05,
            "epoch[1] {} vs single {}", e.batch_ms, single.makespan_ms);
    }

    #[test]
    fn pipelining_period_not_longer_than_batch() {
        for seed in 0..4 {
            let (ms, inst) = setup(10 + seed);
            let s = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap().schedule;
            let e = replay_epoch(&ms, &s, 8);
            assert!(e.period_ms <= e.batch_ms * 1.35 + 1e-6, "period {} vs batch {}", e.period_ms, e.batch_ms);
            assert!(e.epoch_ms >= e.batch_ms);
        }
    }

    #[test]
    fn epoch_grows_with_batches() {
        let (ms, inst) = setup(8);
        let s = greedy::solve(&inst).unwrap();
        let e2 = replay_epoch(&ms, &s, 2);
        let e6 = replay_epoch(&ms, &s, 6);
        assert!(e6.epoch_ms > e2.epoch_ms);
    }
}
