//! Fluid (processor-sharing) uplink pool: the exact completion law of
//! [`LinkMode::Shared`](super::LinkMode::Shared).
//!
//! A helper's uplink sustains `capacity` concurrent transfers at full
//! rate. While `k` transfers are active each progresses at rate
//! `min(1, capacity/k)` — the classic egalitarian processor-sharing
//! fluid. Completion times follow by piecewise-linear advance between
//! events (an arrival or a finish changes `k`); ties are broken
//! deterministically by `(start, input index)`, so finish times are a
//! pure function of the transfer list regardless of thread count or
//! shard order.
//!
//! This module is the *ground truth* the static projection
//! [`TransportCfg::inflate`](super::TransportCfg::inflate) conservatively
//! upper-bounds: with at most `k` transfers ever active, no transfer's
//! rate drops below `capacity/k`, so `finish ≤ start + size·max(1,
//! k/capacity)` — the property suite pins this bound.

/// One transfer offered to a pool: `start` = arrival time, `size` = the
/// transfer's duration at full (dedicated) rate. Units are arbitrary but
/// must match (ms and ms throughout this crate).
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub start: f64,
    pub size: f64,
}

/// Exact fluid finish times of `transfers` sharing one pool of the given
/// `capacity` (> 0). Returns finish times **in input order**. Zero-size
/// transfers finish at their start. O(n²) worst case — pools are
/// per-helper and per-batch, so n is a helper's member count.
pub fn finish_times(transfers: &[Transfer], capacity: f64) -> Vec<f64> {
    assert!(capacity.is_finite() && capacity > 0.0, "capacity must be finite and > 0");
    let n = transfers.len();
    let mut done = vec![0.0f64; n];
    if n == 0 {
        return done;
    }
    for t in transfers {
        assert!(t.start.is_finite() && t.start >= 0.0, "transfer start must be finite and >= 0");
        assert!(t.size.is_finite() && t.size >= 0.0, "transfer size must be finite and >= 0");
    }
    // Deterministic arrival order: (start, input index).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        transfers[a].start.partial_cmp(&transfers[b].start).unwrap().then(a.cmp(&b))
    });
    let mut rem: Vec<f64> = transfers.iter().map(|t| t.size).collect();
    let mut active: Vec<usize> = Vec::new();
    let mut ptr = 0usize;
    let mut now = 0.0f64;
    const EPS: f64 = 1e-9;
    while ptr < n || !active.is_empty() {
        if active.is_empty() {
            // Jump to the next arrival.
            now = now.max(transfers[order[ptr]].start);
        } else {
            let rate = (capacity / active.len() as f64).min(1.0);
            let min_rem = active.iter().map(|&i| rem[i]).fold(f64::INFINITY, f64::min);
            let finish_at = now + min_rem / rate;
            let next_arr = if ptr < n { transfers[order[ptr]].start } else { f64::INFINITY };
            let step_to = finish_at.min(next_arr);
            let dt = step_to - now;
            if dt > 0.0 {
                for &i in &active {
                    rem[i] -= dt * rate;
                }
                now = step_to;
            }
        }
        // Retire finished transfers (deterministic scan in active order).
        let mut k = 0;
        while k < active.len() {
            let i = active[k];
            if rem[i] <= EPS {
                done[i] = now;
                active.remove(k);
            } else {
                k += 1;
            }
        }
        // Admit every transfer that has arrived by `now`.
        while ptr < n && transfers[order[ptr]].start <= now + EPS {
            let i = order[ptr];
            ptr += 1;
            if rem[i] <= EPS {
                done[i] = transfers[i].start; // zero-size: instantaneous
            } else {
                active.push(i);
            }
        }
    }
    done
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn t(start: f64, size: f64) -> Transfer {
        Transfer { start, size }
    }

    #[test]
    fn lone_transfer_runs_at_full_rate() {
        let f = finish_times(&[t(3.0, 10.0)], 2.0);
        assert!((f[0] - 13.0).abs() < 1e-9);
    }

    #[test]
    fn under_capacity_everyone_is_dedicated() {
        // capacity ≥ concurrent transfers → finish = start + size exactly.
        let xs = [t(0.0, 5.0), t(1.0, 3.0), t(2.0, 7.0)];
        let f = finish_times(&xs, 3.0);
        for (i, x) in xs.iter().enumerate() {
            assert!((f[i] - (x.start + x.size)).abs() < 1e-9, "transfer {i}");
        }
    }

    #[test]
    fn two_equal_transfers_on_unit_pool_halve_rate() {
        // Both arrive at 0, size 10, capacity 1: each runs at rate ½ and
        // both finish at 20 (processor sharing, not FIFO).
        let f = finish_times(&[t(0.0, 10.0), t(0.0, 10.0)], 1.0);
        assert!((f[0] - 20.0).abs() < 1e-9);
        assert!((f[1] - 20.0).abs() < 1e-9);
    }

    #[test]
    fn staggered_arrival_piecewise_progress() {
        // A(0, size 10), B(5, size 10), capacity 1. A runs alone on
        // [0,5) (5 done), then shares: both at rate ½. A's remaining 5
        // takes 10 → finishes 15; B then runs alone: 5 + ... B did 5 by
        // t=15, remaining 5 at rate 1 → 20.
        let f = finish_times(&[t(0.0, 10.0), t(5.0, 10.0)], 1.0);
        assert!((f[0] - 15.0).abs() < 1e-9, "A {}", f[0]);
        assert!((f[1] - 20.0).abs() < 1e-9, "B {}", f[1]);
    }

    #[test]
    fn zero_size_is_instant() {
        let f = finish_times(&[t(4.0, 0.0), t(0.0, 100.0)], 1.0);
        assert_eq!(f[0], 4.0);
    }

    #[test]
    fn bytes_conserved() {
        // Total work equals the integral of pool throughput: for any
        // input, Σ size = Σ over pieces of (rate × k × dt). Checked
        // indirectly: every finish ≥ start + size (rate never exceeds 1)
        // and the makespan lower-bounds total size / capacity.
        prop::check(60, |rng| {
            let n = rng.range_usize(1, 12);
            let xs: Vec<Transfer> =
                (0..n).map(|_| t(rng.range_f64(0.0, 50.0), rng.range_f64(0.1, 30.0))).collect();
            let cap = rng.range_f64(0.5, 6.0);
            let f = finish_times(&xs, cap);
            let total: f64 = xs.iter().map(|x| x.size).sum();
            let first = xs.iter().map(|x| x.start).fold(f64::INFINITY, f64::min);
            let last = f.iter().cloned().fold(0.0, f64::max);
            for (i, x) in xs.iter().enumerate() {
                prop::assert_prop(f[i] >= x.start + x.size - 1e-6, "rate cap 1: finish >= start+size");
            }
            // Pool can't process faster than `capacity` in aggregate.
            prop::assert_prop(
                last - first >= total / cap.max(xs.len() as f64) - 1e-6,
                "aggregate throughput bound",
            );
        });
    }

    #[test]
    fn completion_monotone_in_capacity() {
        prop::check(40, |rng| {
            let n = rng.range_usize(1, 10);
            let xs: Vec<Transfer> =
                (0..n).map(|_| t(rng.range_f64(0.0, 20.0), rng.range_f64(0.1, 15.0))).collect();
            let lo = finish_times(&xs, 1.0);
            let hi = finish_times(&xs, 4.0);
            for i in 0..n {
                prop::assert_prop(hi[i] <= lo[i] + 1e-6, "more capacity never delays a transfer");
            }
        });
    }

    #[test]
    fn permutation_invariant() {
        prop::check(40, |rng| {
            let n = rng.range_usize(2, 10);
            let xs: Vec<Transfer> =
                (0..n).map(|_| t(rng.range_f64(0.0, 20.0), rng.range_f64(0.1, 15.0))).collect();
            let f = finish_times(&xs, 2.0);
            // Reverse the input; outputs must follow the permutation.
            let rev: Vec<Transfer> = xs.iter().rev().cloned().collect();
            let fr = finish_times(&rev, 2.0);
            for i in 0..n {
                prop::assert_prop(
                    (f[i] - fr[n - 1 - i]).abs() < 1e-6,
                    "finish times are a function of (start,size), not input order",
                );
            }
        });
    }

    #[test]
    fn static_inflation_upper_bounds_fluid() {
        // The solver-side projection factor(k) with k = pool population
        // is a true upper bound on the fluid finish.
        prop::check(40, |rng| {
            let n = rng.range_usize(1, 10);
            let xs: Vec<Transfer> =
                (0..n).map(|_| t(rng.range_f64(0.0, 10.0), rng.range_f64(0.1, 10.0))).collect();
            let cap = rng.range_f64(0.5, 4.0);
            let f = finish_times(&xs, cap);
            let factor = (n as f64 / cap).max(1.0);
            for (i, x) in xs.iter().enumerate() {
                // A transfer is active from start to finish and its rate
                // never drops below min(1, cap/n), so
                // finish ≤ start + size · factor(n) exactly.
                prop::assert_prop(
                    f[i] <= x.start + x.size * factor + 1e-6,
                    "static factor bounds fluid finish",
                );
            }
        });
    }

    #[test]
    fn deterministic_across_calls() {
        let xs = [t(0.0, 3.0), t(0.5, 2.0), t(0.5, 4.0), t(1.0, 1.0)];
        let a = finish_times(&xs, 1.5);
        let b = finish_times(&xs, 1.5);
        assert_eq!(a, b, "bitwise deterministic");
    }
}
