//! Transport layer: **all** transfer-time computation lives here.
//!
//! The paper's delay model (§III) treats each client↔helper transfer as
//! an independent fixed-delay edge. "Split Learning over Wireless
//! Networks" (arxiv 2204.08119, PAPERS.md) shows the dominant real-world
//! effect is *shared* uplink capacity: concurrent activation/gradient
//! transfers to the same helper contend for bandwidth, so transfer time
//! depends on who else is talking. This module owns that distinction as
//! a closed mode enum:
//!
//! * [`LinkMode::Dedicated`] — today's fixed per-edge delays. Every
//!   projection through a dedicated [`TransportCfg`] is the identity, so
//!   solver decisions and artifacts are **byte-identical** to the
//!   pre-transport code (pinned by `tests/transport_equiv.rs` and the CI
//!   byte-diff gate).
//! * [`LinkMode::Shared`] — per-helper capacity pools: a helper's uplink
//!   sustains `capacity` concurrent transfers at full rate; `k` active
//!   transfers each progress at `capacity/k` of their dedicated rate
//!   (capped at 1×). The exact fluid (processor-sharing) completion law
//!   lives in [`pool`]; the solvers consume the conservative *static*
//!   projection [`TransportCfg::inflate`], which scales a helper row's
//!   transfer delays by the worst-case concurrency factor
//!   `max(1, k/capacity)` — an upper bound on the pooled finish times
//!   (proven against [`pool::finish_times`] in the property suite).
//!
//! Consumers: `instance/scenario.rs` expresses link regimes through the
//! dedicated projection, `solver/strategy.rs` routes on the
//! [`contention`](TransportCfg::contention) signal and re-schedules under
//! the inflated instance, `Schedule::violations_under` checks feasibility
//! against the same projection, the `sim` replay engines resolve transfer
//! phases through [`TransportCfg::inflate_ms`], and the fleet orchestrator
//! carries a `TransportCfg` end-to-end (CLI `--link-model` /
//! `--uplink-capacity`, grid axis `--uplink-capacities`).

pub mod pool;

use crate::instance::{Instance, InstanceMs};
use crate::solver::schedule::Assignment;

/// Closed set of link models (the ISSUE's `LinkModel`; named `LinkMode`
/// because [`crate::instance::network::LinkModel`] already names the
/// statistical rate-draw model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkMode {
    /// Fixed per-edge delays — the paper's §III model, byte-identical to
    /// the pre-transport code path.
    Dedicated,
    /// Per-helper shared uplink pools with processor-sharing contention.
    Shared,
}

impl LinkMode {
    pub fn name(self) -> &'static str {
        match self {
            LinkMode::Dedicated => "dedicated",
            LinkMode::Shared => "shared",
        }
    }

    /// Inverse of [`LinkMode::name`] — CLI flags and fleet checkpoints
    /// round-trip through this.
    pub fn parse(s: &str) -> Option<LinkMode> {
        match s {
            "dedicated" => Some(LinkMode::Dedicated),
            "shared" => Some(LinkMode::Shared),
            _ => None,
        }
    }
}

/// A link mode plus its capacity parameter: the one value threaded from
/// the CLI through solver, simulator, fleet and analytics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransportCfg {
    pub mode: LinkMode,
    /// Concurrent full-rate transfers a helper's uplink sustains
    /// (dimensionless; > 0). Only consulted under [`LinkMode::Shared`].
    pub capacity: f64,
}

/// Default shared-pool capacity when `--link-model shared` is given
/// without `--uplink-capacity`.
pub const DEFAULT_UPLINK_CAPACITY: f64 = 4.0;

impl Default for TransportCfg {
    fn default() -> Self {
        TransportCfg::dedicated()
    }
}

impl TransportCfg {
    /// The identity transport: every projection returns its input.
    pub fn dedicated() -> TransportCfg {
        TransportCfg { mode: LinkMode::Dedicated, capacity: DEFAULT_UPLINK_CAPACITY }
    }

    /// Shared-uplink transport with the given pool capacity (> 0).
    pub fn shared(capacity: f64) -> TransportCfg {
        assert!(capacity.is_finite() && capacity > 0.0, "uplink capacity must be finite and > 0");
        TransportCfg { mode: LinkMode::Shared, capacity }
    }

    #[inline]
    pub fn is_dedicated(&self) -> bool {
        self.mode == LinkMode::Dedicated
    }

    /// Worst-case slowdown of a transfer on a helper with `k` pool
    /// members: `max(1, k/capacity)` under [`LinkMode::Shared`], always
    /// `1` under [`LinkMode::Dedicated`]. This is the static projection
    /// of the fluid pool — an upper bound on realized contention because
    /// at most `k` transfers can ever be simultaneously active.
    #[inline]
    pub fn factor(&self, k: usize) -> f64 {
        match self.mode {
            LinkMode::Dedicated => 1.0,
            LinkMode::Shared => (k as f64 / self.capacity).max(1.0),
        }
    }

    /// Contention signal for the §VII pick rule: excess slowdown of a
    /// uniformly-loaded helper (`factor(ceil(J/I)) − 1`); 0 under
    /// [`LinkMode::Dedicated`] and whenever capacity covers the load.
    pub fn contention(&self, n_clients: usize, n_helpers: usize) -> f64 {
        if self.is_dedicated() || n_helpers == 0 {
            return 0.0;
        }
        self.factor(n_clients.div_ceil(n_helpers)) - 1.0
    }

    /// Project a slotted instance through the transport: helper row `i`'s
    /// transfer delays (r, l, l', r') are scaled by `factor(loads[i])`
    /// (ceil back to whole slots); processing times (p, p') are
    /// unchanged — contention is a *link* effect. Dedicated mode returns
    /// a clone (byte-identical downstream decisions).
    pub fn inflate(&self, inst: &Instance, loads: &[usize]) -> Instance {
        if self.is_dedicated() {
            return inst.clone();
        }
        assert_eq!(loads.len(), inst.n_helpers, "one load per helper");
        let mut out = inst.clone();
        for i in 0..inst.n_helpers {
            let f = self.factor(loads[i]);
            if f <= 1.0 {
                continue;
            }
            for v in [&mut out.r, &mut out.l, &mut out.lp, &mut out.rp] {
                for e in i * inst.n_clients..(i + 1) * inst.n_clients {
                    v[e] = (v[e] as f64 * f).ceil() as u32;
                }
            }
        }
        out
    }

    /// [`inflate`](Self::inflate) for the continuous instance — the sim
    /// replay engines resolve transfer phases through this so simulator
    /// and solver can never disagree about effective rates.
    pub fn inflate_ms(&self, inst: &InstanceMs, loads: &[usize]) -> InstanceMs {
        if self.is_dedicated() {
            return inst.clone();
        }
        assert_eq!(loads.len(), inst.n_helpers, "one load per helper");
        let mut out = inst.clone();
        for i in 0..inst.n_helpers {
            let f = self.factor(loads[i]);
            if f <= 1.0 {
                continue;
            }
            for v in [&mut out.r_ms, &mut out.l_ms, &mut out.lp_ms, &mut out.rp_ms] {
                for e in i * inst.n_clients..(i + 1) * inst.n_clients {
                    v[e] *= f;
                }
            }
        }
        out
    }

    /// Inflate under the uniform-load estimate `ceil(J/I)` on every
    /// helper — what the assignment-shaping solve uses before per-helper
    /// member counts exist.
    pub fn inflate_uniform(&self, inst: &Instance) -> Instance {
        if self.is_dedicated() || inst.n_helpers == 0 {
            return inst.clone();
        }
        let k = inst.n_clients.div_ceil(inst.n_helpers);
        self.inflate(inst, &vec![k; inst.n_helpers])
    }

    /// Per-helper pool loads of a concrete assignment (member counts).
    pub fn loads_of(assignment: &Assignment, n_helpers: usize) -> Vec<usize> {
        let mut loads = vec![0usize; n_helpers];
        for &i in &assignment.helper_of {
            if i < n_helpers {
                loads[i] += 1;
            }
        }
        loads
    }

    /// Inflate for a concrete assignment's per-helper member counts.
    pub fn inflate_for_assignment(&self, inst: &Instance, assignment: &Assignment) -> Instance {
        if self.is_dedicated() {
            return inst.clone();
        }
        self.inflate(inst, &Self::loads_of(assignment, inst.n_helpers))
    }

    /// [`inflate_for_assignment`](Self::inflate_for_assignment) on the
    /// continuous instance.
    pub fn inflate_ms_for_assignment(&self, inst: &InstanceMs, assignment: &Assignment) -> InstanceMs {
        if self.is_dedicated() {
            return inst.clone();
        }
        self.inflate_ms(inst, &Self::loads_of(assignment, inst.n_helpers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::util::prop;

    fn inst(seed: u64) -> Instance {
        ScenarioCfg::new(Scenario::S2, Model::ResNet101, 12, 3, seed).generate().quantize(180.0)
    }

    #[test]
    fn mode_names_roundtrip() {
        for m in [LinkMode::Dedicated, LinkMode::Shared] {
            assert_eq!(LinkMode::parse(m.name()), Some(m));
        }
        assert_eq!(LinkMode::parse("bogus"), None);
    }

    #[test]
    fn dedicated_projections_are_identity() {
        let t = TransportCfg::dedicated();
        let i = inst(1);
        let out = t.inflate(&i, &vec![100; i.n_helpers]);
        assert_eq!(out.r, i.r);
        assert_eq!(out.l, i.l);
        assert_eq!(out.lp, i.lp);
        assert_eq!(out.rp, i.rp);
        assert_eq!(out.p, i.p);
        assert_eq!(t.factor(1000), 1.0);
        assert_eq!(t.contention(1000, 2), 0.0);
        let ms = i.to_ms();
        let out_ms = t.inflate_ms(&ms, &vec![100; i.n_helpers]);
        assert_eq!(out_ms.r_ms, ms.r_ms);
        assert_eq!(out_ms.l_ms, ms.l_ms);
    }

    #[test]
    fn shared_factor_kicks_in_above_capacity() {
        let t = TransportCfg::shared(4.0);
        assert_eq!(t.factor(0), 1.0);
        assert_eq!(t.factor(4), 1.0);
        assert_eq!(t.factor(8), 2.0);
        assert!((t.contention(16, 2) - 1.0).abs() < 1e-12); // ceil(16/2)=8 → 2× → 1.0 excess
        assert_eq!(t.contention(4, 2), 0.0);
    }

    #[test]
    fn inflate_scales_only_overloaded_helper_rows() {
        let t = TransportCfg::shared(2.0);
        let i = inst(3);
        let loads = vec![1usize, 4, 2]; // helper 1 is 2× overloaded
        let out = t.inflate(&i, &loads);
        let jn = i.n_clients;
        for e in 0..jn {
            assert_eq!(out.r[e], i.r[e], "helper 0 untouched");
            assert_eq!(out.r[2 * jn + e], i.r[2 * jn + e], "helper 2 at capacity");
            assert_eq!(out.r[jn + e], (i.r[jn + e] as f64 * 2.0).ceil() as u32);
            assert_eq!(out.l[jn + e], (i.l[jn + e] as f64 * 2.0).ceil() as u32);
        }
        // Processing times never inflate.
        assert_eq!(out.p, i.p);
        assert_eq!(out.pp, i.pp);
        assert_eq!(out.d, i.d);
    }

    #[test]
    fn inflate_monotone_in_capacity() {
        prop::check(20, |rng| {
            let i = inst(rng.next_u64());
            let loads = vec![rng.range_usize(1, 20); i.n_helpers];
            let lo = TransportCfg::shared(1.0).inflate(&i, &loads);
            let hi = TransportCfg::shared(8.0).inflate(&i, &loads);
            for e in 0..i.r.len() {
                prop::assert_prop(lo.r[e] >= hi.r[e], "more capacity never slows a transfer");
                prop::assert_prop(hi.r[e] >= i.r[e], "inflation never speeds up");
            }
        });
    }

    #[test]
    fn loads_of_counts_members() {
        let a = Assignment::new(vec![1, 0, 1, 1, 2]);
        assert_eq!(TransportCfg::loads_of(&a, 4), vec![1, 3, 1, 0]);
    }

    #[test]
    fn inflated_instance_stays_valid() {
        let t = TransportCfg::shared(1.5);
        let i = inst(9);
        let out = t.inflate_uniform(&i);
        assert!(out.to_ms().validate().is_ok());
        assert_eq!(out.n_clients, i.n_clients);
        assert!(out.horizon() >= i.horizon());
    }
}
