//! The leader: resolves CLI-level requests into instances, solver runs and
//! comparative reports. This is the orchestration entry the examples and
//! the `psl` binary share.

use crate::instance::profiles::Model;
use crate::instance::scenario::{Scenario, ScenarioCfg};
use crate::instance::{Instance, InstanceMs};
use crate::sim;
use crate::solver::{admm, baseline, exact, greedy, strategy};
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::time::Instant;

/// A fully-specified solve request.
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub scenario: Scenario,
    pub model: Model,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    /// None → the model's default |S_t| (§VII: 180 ms ResNet, 550 ms VGG).
    pub slot_ms: Option<f64>,
    pub switch_cost_ms: f64,
}

impl SolveRequest {
    pub fn instance_ms(&self) -> InstanceMs {
        ScenarioCfg::new(self.scenario, self.model, self.n_clients, self.n_helpers, self.seed)
            .with_switch_cost(self.switch_cost_ms)
            .generate()
    }

    pub fn slot_ms(&self) -> f64 {
        self.slot_ms.unwrap_or(self.model.profile().default_slot_ms)
    }

    pub fn instance(&self) -> Instance {
        self.instance_ms().quantize(self.slot_ms())
    }
}

/// One method's outcome on an instance.
#[derive(Clone, Debug)]
pub struct MethodOutcome {
    pub method: String,
    pub makespan_slots: u32,
    pub makespan_ms: f64,
    pub realized_ms: Option<f64>,
    pub solve_s: f64,
    pub preemptions: u32,
    pub feasible: bool,
}

/// Run `method` ("admm" | "greedy" | "baseline" | "exact" | "strategy")
/// on the instance; optionally replay in continuous time.
pub fn run_method(
    ms: &InstanceMs,
    inst: &Instance,
    method: &str,
    replay: bool,
    seed: u64,
) -> Result<MethodOutcome> {
    let start = Instant::now();
    let schedule = match method {
        "admm" => admm::solve(inst, &admm::AdmmCfg::default()).context("admm infeasible")?.schedule,
        "greedy" => greedy::solve(inst).context("greedy infeasible")?,
        "baseline" => baseline::solve(inst, &mut Rng::seeded(seed ^ 0xBA5E)).context("baseline infeasible")?,
        "exact" => exact::solve(inst, &exact::ExactCfg::default()).schedule,
        "strategy" => strategy::solve(inst, &admm::AdmmCfg::default()).context("strategy infeasible")?.0,
        other => anyhow::bail!("unknown method {other}"),
    };
    let solve_s = start.elapsed().as_secs_f64();
    let makespan = schedule.makespan(inst);
    let realized = if replay { Some(sim::replay(ms, &schedule, None).makespan_ms) } else { None };
    Ok(MethodOutcome {
        method: method.to_string(),
        makespan_slots: makespan,
        makespan_ms: makespan as f64 * inst.slot_ms,
        realized_ms: realized,
        solve_s,
        preemptions: schedule.preemptions(),
        feasible: schedule.is_feasible(inst),
    })
}

/// Compare all practical methods on one request (the `psl solve` default).
pub fn compare_methods(req: &SolveRequest, include_exact: bool, replay: bool) -> Result<Vec<MethodOutcome>> {
    let ms = req.instance_ms();
    let inst = ms.quantize(req.slot_ms());
    let mut methods = vec!["strategy", "admm", "greedy", "baseline"];
    if include_exact {
        methods.push("exact");
    }
    methods
        .into_iter()
        .map(|m| run_method(&ms, &inst, m, replay, req.seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> SolveRequest {
        SolveRequest {
            scenario: Scenario::S2,
            model: Model::Vgg19,
            n_clients: 8,
            n_helpers: 2,
            seed: 5,
            slot_ms: None,
            switch_cost_ms: 0.0,
        }
    }

    #[test]
    fn compare_produces_feasible_outcomes() {
        let rows = compare_methods(&req(), false, true).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.feasible, "{} infeasible", r.method);
            assert!(r.makespan_slots > 0);
            assert!(r.realized_ms.unwrap() <= r.makespan_ms + 1e-6);
        }
        // Strategy must not lose to the baseline.
        let strat = rows.iter().find(|r| r.method == "strategy").unwrap();
        let base = rows.iter().find(|r| r.method == "baseline").unwrap();
        assert!(strat.makespan_slots <= base.makespan_slots);
    }

    #[test]
    fn default_slot_is_models() {
        assert_eq!(req().slot_ms(), 550.0);
    }
}
