//! Training-round orchestration: instance → schedule → real PJRT training
//! via the slexec driver. Used by `psl train` and examples/e2e_train.rs.

use super::leader::SolveRequest;
use crate::instance::profiles::Model;
use crate::instance::scenario::Scenario;
use crate::runtime::Engine;
use crate::slexec::{Driver, SplitModel, TrainCfg, TrainReport};
use crate::solver::{admm, strategy};
use anyhow::{Context, Result};
use std::sync::Arc;

/// End-to-end training request: a fleet of J clients / I helpers running
/// `arch` artifacts, scheduled by the paper's solution strategy over a
/// profiled instance of matching shape.
#[derive(Clone, Debug)]
pub struct TrainRequest {
    pub arch: String,
    pub artifacts_dir: std::path::PathBuf,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    pub train: TrainCfg,
}

/// Outcome: the schedule diagnostics + the training report.
#[derive(Debug)]
pub struct TrainOutcome {
    pub method: &'static str,
    pub makespan_slots: u32,
    pub report: TrainReport,
}

/// The profiled instance backing the runtime fleet: the executable archs
/// map onto the paper's testbed models (vgg_mini→VGG19, resnet_mini→
/// ResNet101) so schedules reflect the published delay structure.
pub fn fleet_instance(req: &TrainRequest) -> crate::instance::Instance {
    let model = if req.arch.contains("vgg") { Model::Vgg19 } else { Model::ResNet101 };
    SolveRequest {
        scenario: Scenario::S2,
        model,
        n_clients: req.n_clients,
        n_helpers: req.n_helpers,
        seed: req.seed,
        slot_ms: None,
        switch_cost_ms: 0.0,
    }
    .instance()
}

/// Solve the fleet's schedule and run real training with it.
pub fn run(req: &TrainRequest) -> Result<TrainOutcome> {
    let inst = fleet_instance(req);
    let (schedule, method) =
        strategy::solve(&inst, &admm::AdmmCfg::default()).context("schedule infeasible")?;
    let method = method.name();
    let makespan = schedule.makespan(&inst);
    crate::log_info!(
        "fleet J={} I={}: method {method}, makespan {} slots ({:.1} s nominal)",
        req.n_clients,
        req.n_helpers,
        makespan,
        makespan as f64 * inst.slot_ms / 1000.0
    );
    let engine = Arc::new(Engine::cpu()?);
    let model = SplitModel::load(engine, &req.artifacts_dir, &req.arch)?;
    let mut driver = Driver::new(model, &inst, schedule, req.seed)?;
    let report = driver.train(&req.train)?;
    Ok(TrainOutcome { method, makespan_slots: makespan, report })
}

// Integration coverage for `run` lives in rust/tests/e2e_train.rs (gated
// on artifacts); unit tests here cover the instance mapping only.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_instance_matches_request_shape() {
        let req = TrainRequest {
            arch: "vgg_mini".into(),
            artifacts_dir: "artifacts".into(),
            n_clients: 6,
            n_helpers: 2,
            seed: 3,
            train: TrainCfg::default(),
        };
        let inst = fleet_instance(&req);
        assert_eq!(inst.n_clients, 6);
        assert_eq!(inst.n_helpers, 2);
        assert_eq!(inst.slot_ms, 550.0, "vgg fleet uses VGG19 slotting");
    }
}
