//! The L3 leader: request resolution, method comparison, and training
//! round orchestration — the glue between solvers, simulator and the SL
//! runtime.

pub mod leader;
pub mod rounds;

pub use leader::{compare_methods, run_method, MethodOutcome, SolveRequest};
pub use rounds::{run as run_training, TrainOutcome, TrainRequest};
