//! Chrome trace-event export: [`TraceData`] → the `psl-trace` artifact.
//!
//! The document is the Chrome trace-event JSON "object format" — a
//! top-level `traceEvents` array of complete (`"ph": "X"`) duration
//! events plus `thread_name` metadata events — so it loads directly in
//! `chrome://tracing` or <https://ui.perfetto.dev>. Both viewers ignore
//! unknown top-level keys, which is where the artifact envelope (`kind`,
//! `schema_version`) and the deterministic `counters` object live.
//!
//! Span `ts`/`dur` values are wall-clock microseconds since the process
//! epoch and are **non-deterministic**; the `counters` object carries the
//! deterministic algorithm statistics (see [`crate::obs`]'s determinism
//! contract). The `note` field restates this split for human readers.

use super::recorder::TraceData;
use crate::bench::artifact::{self, ArtifactKind};
use crate::util::json::Json;
use anyhow::{Context, Result};

/// Serialize a capture as a `psl-trace` artifact document.
pub fn trace_to_json(data: &TraceData) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (tid, name) in &data.threads {
        events.push(Json::obj(vec![
            ("args", Json::obj(vec![("name", Json::Str(name.clone()))])),
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(*tid as f64)),
        ]));
    }
    for s in &data.spans {
        let mut pairs = vec![
            ("cat", Json::Str(s.cat.to_string())),
            ("dur", Json::Num(s.dur_us as f64)),
            ("name", Json::Str(s.name.to_string())),
            ("ph", Json::Str("X".to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(s.tid as f64)),
            ("ts", Json::Num(s.start_us as f64)),
        ];
        if !s.args.is_empty() {
            pairs.push(("args", Json::obj(s.args.iter().map(|(k, v)| (*k, Json::Num(*v as f64))).collect())));
        }
        events.push(Json::obj(pairs));
    }
    let counters = Json::obj(data.counters.iter().map(|(k, v)| (*k, Json::Num(*v as f64))).collect());
    artifact::envelope(
        ArtifactKind::Trace,
        vec![
            ("counters", counters),
            ("displayTimeUnit", Json::Str("ms".to_string())),
            (
                "note",
                Json::Str(
                    "traceEvents ts/dur are wall-clock microseconds (non-deterministic); \
                     counters are deterministic algorithm statistics"
                        .to_string(),
                ),
            ),
            ("traceEvents", Json::Arr(events)),
        ],
    )
}

/// Write a capture as pretty-printed trace JSON at a user-chosen path
/// (unlike the registry's `save`, `--trace FILE` takes a full path;
/// parent directories are created). Returns the path written.
pub fn write_trace(path: &str, data: &TraceData) -> Result<std::path::PathBuf> {
    let doc = trace_to_json(data);
    let p = std::path::Path::new(path);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
        }
    }
    std::fs::write(p, doc.pretty()).with_context(|| format!("write trace {path}"))?;
    Ok(p.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{counter_add, span, Recording};

    fn sample() -> TraceData {
        let rec = Recording::start();
        {
            let mut s = span("test", "trace/sample");
            s.arg("n", 7);
        }
        counter_add("trace.count", 3);
        rec.finish()
    }

    #[test]
    fn export_is_valid_trace_event_json() {
        let data = sample();
        let doc = trace_to_json(&data);
        assert_eq!(artifact::validate(&doc).unwrap(), ArtifactKind::Trace);
        let events = doc.get("traceEvents").as_arr().unwrap();
        // One thread_name metadata event + one duration event.
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").as_str(), Some("M"));
        let e = &events[1];
        assert_eq!(e.get("ph").as_str(), Some("X"));
        assert_eq!(e.get("name").as_str(), Some("trace/sample"));
        assert_eq!(e.get("cat").as_str(), Some("test"));
        assert_eq!(e.get("args").get("n").as_usize(), Some(7));
        assert!(e.get("ts").as_f64().is_some() && e.get("dur").as_f64().is_some());
        assert_eq!(doc.get("counters").get("trace.count").as_usize(), Some(3));
        // Round-trips through the parser (what the CI smoke validates).
        assert_eq!(Json::parse(&doc.pretty()).unwrap(), doc);
    }

    #[test]
    fn write_trace_creates_parent_dirs_and_roundtrips() {
        let data = sample();
        let dir = std::env::temp_dir().join(format!("psl-trace-test-{}", std::process::id()));
        let path = dir.join("nested").join("t.json");
        let written = write_trace(path.to_str().unwrap(), &data).unwrap();
        let doc = artifact::load_expecting(written.to_str().unwrap(), ArtifactKind::Trace).unwrap();
        assert_eq!(doc, trace_to_json(&data));
        std::fs::remove_dir_all(&dir).ok();
    }
}
