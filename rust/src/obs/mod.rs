//! # obs — in-process tracing & counter subsystem
//!
//! Zero-dependency, thread-aware observability for the solver, shard,
//! fleet and exec layers. Two kinds of signal flow through one
//! [`Recording`]:
//!
//! * **Spans** — RAII guards ([`span`] / the [`obs_span!`](crate::obs_span)
//!   macro) that measure wall-clock phase durations on per-thread buffers,
//!   merged deterministically (sorted by start time, thread, name) at
//!   flush. Durations are **non-deterministic** by nature and are never
//!   read by any decision path.
//! * **Counters** — deterministic algorithm statistics ([`counter_add`] /
//!   [`counter_max`]): exact-solver nodes expanded / cutoffs / max depth,
//!   ADMM iterations and residuals, repair moves, shard cells and
//!   migrations, pool invocations. Counter updates are commutative
//!   (sums and maxes of per-phase totals), so the final counter map is
//!   **byte-identical across thread counts** — pinned by
//!   `tests/obs_equiv.rs`.
//!
//! ## The determinism contract
//!
//! Instrumentation is strictly *read-only* with respect to scheduling:
//! no solver, shard, fleet or serve decision ever reads a span or a
//! counter, so every decision-bearing artifact (`psl-sweep`, `psl-fleet`,
//! `psl-shard`, checkpoints, rounds JSONL) is byte-identical with tracing
//! on or off. CI diffs a traced `psl fleet` run against an untraced one
//! to hold the line.
//!
//! ## Recording model
//!
//! [`Recording::start`] claims a process-wide exclusive recording (a
//! second concurrent `start` blocks — recordings serialize), enrolls the
//! calling thread, and clears the sink. Worker threads join a recording
//! by adopting the spawner's token ([`current_token`] /
//! [`adopt_token`] — [`crate::exec::pool`] does this automatically), so
//! spans and counters from pool workers land in the active recording
//! while unrelated threads (e.g. parallel test threads) stay invisible.
//! [`Recording::finish`] returns the merged [`TraceData`].
//!
//! ## Export
//!
//! [`write_trace`] serializes a [`TraceData`] as a Chrome trace-event
//! JSON document (the `psl-trace` artifact kind, schema-versioned via
//! [`crate::bench::artifact`]) loadable in `chrome://tracing` or
//! Perfetto. `psl solve|fleet|shard|serve --trace FILE` emit it;
//! `psl analyze --trace FILE` renders per-phase duration and counter
//! summary tables ([`crate::analyze::trace`]).
//!
//! The logger ([`crate::util::logger`]) shares this module's relative
//! clock ([`epoch`]), so stderr log timestamps and span `ts` values are
//! directly comparable.

mod recorder;
pub mod trace;

pub use recorder::{
    adopt_token, counter_add, counter_max, current_token, enabled, epoch, flush_thread, now_us,
    span, Recording, Span, SpanRec, TraceData,
};
pub use trace::{trace_to_json, write_trace};
