//! The span + counter recorder behind [`crate::obs`].
//!
//! One global sink, one active recording at a time (recordings hold an
//! exclusivity lock and therefore serialize — `cargo test`'s parallel
//! test threads cannot pollute each other's counters). Threads
//! participate only when enrolled: the recording's starter is enrolled
//! automatically, pool workers adopt the spawner's token, and everything
//! else no-ops at the price of one thread-local read.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// The process-wide relative-clock epoch shared by trace spans and the
/// stderr logger, so log-line timestamps and span `ts` values line up.
pub fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since [`epoch`].
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Per-thread span buffer capacity before an automatic flush to the sink.
const BUF_FLUSH: usize = 256;

/// One finished span, as merged into [`TraceData`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRec {
    /// Category (the subsystem: "solver", "shard", "fleet", "exec", …).
    pub cat: &'static str,
    /// Phase name ("fleet/decide", "admm/solve-fwd", …).
    pub name: &'static str,
    /// Recorder-assigned thread id (0 = first thread that ever recorded).
    pub tid: u64,
    /// Start, µs since [`epoch`].
    pub start_us: u64,
    /// Duration, µs (wall-clock — non-deterministic).
    pub dur_us: u64,
    /// Optional integer annotations (e.g. serve round latency).
    pub args: Vec<(&'static str, u64)>,
}

/// Everything one [`Recording`] captured.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceData {
    /// Spans from every enrolled thread, sorted by (start, tid, name).
    pub spans: Vec<SpanRec>,
    /// Deterministic counters (sums / maxes of per-phase totals).
    pub counters: BTreeMap<&'static str, u64>,
    /// `(tid, thread name)` for every tid appearing in `spans`.
    pub threads: Vec<(u64, String)>,
}

impl TraceData {
    /// A counter's value, 0 when it was never touched.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }
}

struct Sink {
    spans: Vec<SpanRec>,
    counters: BTreeMap<&'static str, u64>,
}

static SINK: Mutex<Sink> = Mutex::new(Sink { spans: Vec::new(), counters: BTreeMap::new() });
/// Serializes recordings process-wide; held for a [`Recording`]'s lifetime.
static EXCLUSIVE: Mutex<()> = Mutex::new(());
/// Id of the active recording (0 = none).
static ACTIVE: AtomicU64 = AtomicU64::new(0);
static NEXT_RECORDING: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static THREAD_NAMES: Mutex<BTreeMap<u64, String>> = Mutex::new(BTreeMap::new());

thread_local! {
    /// The recording id this thread is enrolled in (0 = none).
    static ENROLLED: Cell<u64> = Cell::new(0);
    /// Recorder-assigned thread id (lazy; `u64::MAX` = unassigned).
    static TID: Cell<u64> = Cell::new(u64::MAX);
    /// This thread's unflushed spans.
    static BUF: RefCell<Vec<SpanRec>> = RefCell::new(Vec::new());
}

/// Lock a recorder mutex, surviving poison (a panicking instrumented
/// thread must not take observability down with it).
fn lock<T>(m: &'static Mutex<T>) -> MutexGuard<'static, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn thread_tid() -> u64 {
    TID.with(|t| {
        if t.get() == u64::MAX {
            let id = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            t.set(id);
            let name = std::thread::current().name().unwrap_or("thread").to_string();
            lock(&THREAD_NAMES).insert(id, name);
        }
        t.get()
    })
}

/// True when the calling thread is enrolled in the active recording —
/// the fast path every instrumentation site checks first.
pub fn enabled() -> bool {
    let tok = ENROLLED.with(|e| e.get());
    tok != 0 && tok == ACTIVE.load(Ordering::Relaxed)
}

/// The calling thread's enrollment token, for handing to spawned
/// workers ([`adopt_token`]). 0 when not enrolled.
pub fn current_token() -> u64 {
    ENROLLED.with(|e| e.get())
}

/// Enroll the calling thread under a token captured on the spawning
/// thread via [`current_token`]. Adopting 0 un-enrolls.
pub fn adopt_token(token: u64) {
    ENROLLED.with(|e| e.set(token));
}

/// Add to a deterministic counter. No-op unless enrolled in the active
/// recording. Only commutative totals belong here (per-phase sums),
/// never per-thread detail — that is what keeps the counter map
/// thread-count invariant.
pub fn counter_add(name: &'static str, delta: u64) {
    if delta == 0 || !enabled() {
        return;
    }
    *lock(&SINK).counters.entry(name).or_insert(0) += delta;
}

/// Raise a deterministic counter to at least `v` (max-merge — also
/// commutative, hence thread-count invariant).
pub fn counter_max(name: &'static str, v: u64) {
    if v == 0 || !enabled() {
        return;
    }
    let mut s = lock(&SINK);
    let e = s.counters.entry(name).or_insert(0);
    if v > *e {
        *e = v;
    }
}

/// An in-flight RAII span. Created by [`span`]; records on drop. A span
/// created outside an active recording is inert (token 0).
pub struct Span {
    token: u64,
    cat: &'static str,
    name: &'static str,
    start_us: u64,
    args: Vec<(&'static str, u64)>,
}

/// Open a span; it records its duration when dropped.
pub fn span(cat: &'static str, name: &'static str) -> Span {
    let token = if enabled() { ENROLLED.with(|e| e.get()) } else { 0 };
    Span {
        token,
        cat,
        name,
        start_us: if token != 0 { now_us() } else { 0 },
        args: Vec::new(),
    }
}

impl Span {
    /// Attach an integer annotation (shown in the trace viewer's args
    /// panel). No-op on inert spans.
    pub fn arg(&mut self, key: &'static str, value: u64) {
        if self.token != 0 {
            self.args.push((key, value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        // Re-check at drop: if the recording finished while this span was
        // open, the record must not leak into the next recording's sink.
        if self.token == 0 || self.token != ACTIVE.load(Ordering::Relaxed) {
            return;
        }
        let rec = SpanRec {
            cat: self.cat,
            name: self.name,
            tid: thread_tid(),
            start_us: self.start_us,
            dur_us: now_us().saturating_sub(self.start_us),
            args: std::mem::take(&mut self.args),
        };
        BUF.with(|b| {
            let mut buf = b.borrow_mut();
            buf.push(rec);
            if buf.len() >= BUF_FLUSH {
                flush_buf(&mut buf);
            }
        });
    }
}

fn flush_buf(buf: &mut Vec<SpanRec>) {
    if buf.is_empty() {
        return;
    }
    if enabled() {
        lock(&SINK).spans.append(buf);
    } else {
        // Stale spans from a recording that already finished: discard.
        buf.clear();
    }
}

/// Flush the calling thread's span buffer into the sink. Pool workers
/// call this before exiting; the recording's own thread is flushed by
/// [`Recording::finish`].
pub fn flush_thread() {
    BUF.with(|b| flush_buf(&mut b.borrow_mut()));
}

/// An exclusive, process-wide recording session. Dropping without
/// [`finish`](Recording::finish) discards the data and releases the
/// exclusivity lock.
pub struct Recording {
    guard: Option<MutexGuard<'static, ()>>,
}

impl Recording {
    /// Start recording: blocks until any other recording finishes,
    /// clears the sink, enrolls the calling thread.
    pub fn start() -> Recording {
        let guard = EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner());
        let id = NEXT_RECORDING.fetch_add(1, Ordering::Relaxed);
        {
            let mut s = lock(&SINK);
            s.spans.clear();
            s.counters.clear();
        }
        BUF.with(|b| b.borrow_mut().clear());
        ACTIVE.store(id, Ordering::SeqCst);
        ENROLLED.with(|e| e.set(id));
        Recording { guard: Some(guard) }
    }

    /// Stop recording and return the merged, deterministically ordered
    /// capture.
    pub fn finish(mut self) -> TraceData {
        let data = finish_active();
        self.guard = None; // releases the exclusivity lock; Drop no-ops
        data
    }
}

impl Drop for Recording {
    fn drop(&mut self) {
        if self.guard.is_some() {
            let _ = finish_active();
        }
    }
}

fn finish_active() -> TraceData {
    flush_thread();
    ACTIVE.store(0, Ordering::SeqCst);
    ENROLLED.with(|e| e.set(0));
    let (mut spans, counters) = {
        let mut s = lock(&SINK);
        (std::mem::take(&mut s.spans), std::mem::take(&mut s.counters))
    };
    spans.sort_by(|a, b| (a.start_us, a.tid, a.name).cmp(&(b.start_us, b.tid, b.name)));
    let names = lock(&THREAD_NAMES);
    let threads = spans
        .iter()
        .map(|s| s.tid)
        .collect::<BTreeSet<u64>>()
        .into_iter()
        .map(|tid| (tid, names.get(&tid).cloned().unwrap_or_else(|| format!("thread-{tid}"))))
        .collect();
    TraceData { spans, counters, threads }
}

/// RAII span guard: `let _s = obs_span!("fleet", "fleet/decide");`.
/// Bind it to a named variable (not `_`) so it lives to scope end.
#[macro_export]
macro_rules! obs_span {
    ($cat:expr, $name:expr) => {
        $crate::obs::span($cat, $name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_max_merge() {
        let rec = Recording::start();
        counter_add("t.sum", 3);
        counter_add("t.sum", 4);
        counter_max("t.max", 5);
        counter_max("t.max", 2);
        let data = rec.finish();
        assert_eq!(data.counter("t.sum"), 7);
        assert_eq!(data.counter("t.max"), 5);
        assert_eq!(data.counter("t.absent"), 0);
    }

    #[test]
    fn everything_is_inert_outside_a_recording() {
        counter_add("t.noise", 99);
        {
            let _s = span("test", "t/noise");
        }
        let rec = Recording::start();
        let data = rec.finish();
        assert!(data.counters.is_empty(), "{:?}", data.counters);
        assert!(data.spans.is_empty());
    }

    #[test]
    fn spans_record_name_cat_and_order() {
        let rec = Recording::start();
        {
            let mut s = span("test", "t/outer");
            s.arg("k", 42);
            let _inner = span("test", "t/inner");
        }
        let data = rec.finish();
        assert_eq!(data.spans.len(), 2);
        // Outer opened first → sorts first on start_us (ties break on name).
        assert_eq!(data.spans[0].name, "t/outer");
        assert_eq!(data.spans[0].cat, "test");
        assert_eq!(data.spans[0].args, vec![("k", 42)]);
        assert_eq!(data.spans[1].name, "t/inner");
        assert_eq!(data.threads.len(), 1);
    }

    #[test]
    fn unenrolled_threads_stay_invisible_enrolled_threads_count() {
        let rec = Recording::start();
        let token = current_token();
        assert_ne!(token, 0);
        // A thread that never adopts the token contributes nothing.
        std::thread::spawn(|| {
            counter_add("t.ghost", 1);
            let _s = span("test", "t/ghost");
        })
        .join()
        .unwrap();
        // A thread that adopts the token contributes (and flushes).
        std::thread::spawn(move || {
            adopt_token(token);
            counter_add("t.worker", 2);
            {
                let _s = span("test", "t/worker");
            }
            flush_thread();
        })
        .join()
        .unwrap();
        let data = rec.finish();
        assert_eq!(data.counter("t.ghost"), 0);
        assert_eq!(data.counter("t.worker"), 2);
        assert!(data.spans.iter().all(|s| s.name != "t/ghost"));
        assert_eq!(data.spans.iter().filter(|s| s.name == "t/worker").count(), 1);
    }

    #[test]
    fn sequential_recordings_are_isolated() {
        let rec = Recording::start();
        counter_add("t.first", 1);
        let first = rec.finish();
        assert_eq!(first.counter("t.first"), 1);
        let rec = Recording::start();
        counter_add("t.second", 1);
        let second = rec.finish();
        assert_eq!(second.counter("t.first"), 0);
        assert_eq!(second.counter("t.second"), 1);
    }

    #[test]
    fn dropping_a_recording_discards_and_unlocks() {
        {
            let _rec = Recording::start();
            counter_add("t.dropped", 1);
        } // dropped without finish
        let rec = Recording::start(); // would deadlock if the lock leaked
        let data = rec.finish();
        assert_eq!(data.counter("t.dropped"), 0);
    }
}
