//! Testbed profile bank: devices (Table I) and model layer profiles
//! (ResNet101 / VGG19 as the paper counts their indivisible "layers").
//!
//! The paper's optimization layer never touches gradients — it only
//! consumes the profiled delay vectors r, p, l, l', p', r'. We embed the
//! paper's published measurements (Table I batch-update times, Fig 5
//! part-1 compute times) as data and derive per-part times from a
//! per-layer cost model, so that changing the cut layers (σ1, σ2) changes
//! the part times exactly the way it does on the real testbed.
//!
//! Units: milliseconds for time, megabytes for activations/params,
//! gigabytes for device memory. All times are for one batch of 128
//! samples (the paper's batch size).

/// One indivisible NN layer: relative compute weight, activation output
/// size (MB, for batch 128), and parameter size (MB).
#[derive(Clone, Copy, Debug)]
pub struct LayerProfile {
    pub flops_weight: f64,
    pub act_mb: f64,
    pub param_mb: f64,
}

/// A model profile: the per-layer table plus measured whole-batch times.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    pub layers: Vec<LayerProfile>,
    /// Default cut layers (σ1, σ2) used in Scenario 1 (paper §VII):
    /// ResNet101 → (3, 33); VGG19 → (3, 23). 1-based, part-1 = [1..σ1].
    pub default_cuts: (usize, usize),
    /// Fraction of a layer's compute that is forward (rest is backward).
    /// Fig 5 shows fwd/bwd asymmetry; VGG's bwd is relatively heavier.
    pub fwd_frac: f64,
    /// Paper's default slot length |S_t| for this model (§VII): 180 ms for
    /// ResNet101, 550 ms for VGG19.
    pub default_slot_ms: f64,
}

/// Which NN the scenario trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Model {
    ResNet101,
    Vgg19,
}

impl Model {
    pub fn name(self) -> &'static str {
        match self {
            Model::ResNet101 => "resnet101",
            Model::Vgg19 => "vgg19",
        }
    }

    pub fn parse(s: &str) -> Option<Model> {
        match s.to_ascii_lowercase().as_str() {
            "resnet101" | "resnet" => Some(Model::ResNet101),
            "vgg19" | "vgg" => Some(Model::Vgg19),
            _ => None,
        }
    }

    /// Build the per-layer profile table.
    ///
    /// The paper treats ResNet101 as 37 indivisible layers (stem + 33
    /// bottleneck blocks + pool + fc + loss) and VGG19 as 25 (16 conv +
    /// 5 pool grouped + 3 fc + loss → 25 entries). The tables below follow
    /// the canonical architectures: compute weight ∝ FLOPs of the block on
    /// 32×32 inputs (CIFAR-10), activation size = output tensor MB at
    /// batch 128, params = weight MB.
    pub fn profile(self) -> ModelProfile {
        match self {
            Model::ResNet101 => ModelProfile {
                name: "resnet101",
                layers: resnet101_layers(),
                default_cuts: (3, 33),
                fwd_frac: 0.38,
                default_slot_ms: 180.0,
            },
            Model::Vgg19 => ModelProfile {
                name: "vgg19",
                layers: vgg19_layers(),
                default_cuts: (3, 23),
                fwd_frac: 0.30,
                default_slot_ms: 550.0,
            },
        }
    }
}

/// ResNet101 on 32×32: stem conv, then bottleneck stages [3, 4, 23, 3],
/// then avgpool+fc. 1 (stem) + 33 (blocks) + 3 (pool, fc, loss) = 37.
fn resnet101_layers() -> Vec<LayerProfile> {
    let mut layers = Vec::with_capacity(37);
    // Stem: conv3x3,64 on 32x32. act: 32*32*64*4B * 128 = 33.5 MB.
    layers.push(LayerProfile { flops_weight: 1.2, act_mb: 33.5, param_mb: 0.007 });
    // Stage conv2_x: 3 bottlenecks @ 32x32, width 64->256.
    for k in 0..3 {
        layers.push(LayerProfile {
            flops_weight: if k == 0 { 2.4 } else { 2.2 },
            act_mb: 134.2, // 32*32*256*4*128 / 1e6
            param_mb: if k == 0 { 0.30 } else { 0.28 },
        });
    }
    // Stage conv3_x: 4 bottlenecks @ 16x16, width 512.
    for k in 0..4 {
        layers.push(LayerProfile {
            flops_weight: if k == 0 { 2.6 } else { 2.2 },
            act_mb: 67.1,
            param_mb: if k == 0 { 1.51 } else { 1.12 },
        });
    }
    // Stage conv4_x: 23 bottlenecks @ 8x8, width 1024 (the bulk).
    for k in 0..23 {
        layers.push(LayerProfile {
            flops_weight: if k == 0 { 2.6 } else { 2.2 },
            act_mb: 33.5,
            param_mb: if k == 0 { 6.03 } else { 4.47 },
        });
    }
    // Stage conv5_x: 3 bottlenecks @ 4x4, width 2048.
    for k in 0..3 {
        layers.push(LayerProfile {
            flops_weight: if k == 0 { 2.6 } else { 2.2 },
            act_mb: 16.8,
            param_mb: if k == 0 { 24.1 } else { 17.9 },
        });
    }
    // avgpool, fc, loss.
    layers.push(LayerProfile { flops_weight: 0.05, act_mb: 1.05, param_mb: 0.0 });
    layers.push(LayerProfile { flops_weight: 0.05, act_mb: 0.005, param_mb: 0.082 });
    layers.push(LayerProfile { flops_weight: 0.02, act_mb: 0.005, param_mb: 0.0 });
    assert_eq!(layers.len(), 37);
    layers
}

/// VGG19 on 32×32: 16 convs (with pools folded into the preceding conv
/// entry, matching the paper's "25 layers" granularity: 16 conv + 5 pool
/// + 3 fc + loss = 25).
fn vgg19_layers() -> Vec<LayerProfile> {
    // (flops_weight, act_mb, param_mb) per entry.
    // conv weights ∝ out_ch * in_ch * H * W; acts at batch 128.
    let spec: [(f64, f64, f64); 25] = [
        (0.6, 33.5, 0.007),  // conv1_1 64@32x32
        (6.2, 33.5, 0.148),  // conv1_2
        (0.05, 8.4, 0.0),    // pool1
        (3.1, 16.8, 0.295),  // conv2_1 128@16x16
        (6.2, 16.8, 0.590),  // conv2_2
        (0.05, 4.2, 0.0),    // pool2
        (3.1, 8.4, 1.18),    // conv3_1 256@8x8
        (6.2, 8.4, 2.36),    // conv3_2
        (6.2, 8.4, 2.36),    // conv3_3
        (6.2, 8.4, 2.36),    // conv3_4
        (0.05, 2.1, 0.0),    // pool3
        (3.1, 4.2, 4.72),    // conv4_1 512@4x4
        (6.2, 4.2, 9.44),    // conv4_2
        (6.2, 4.2, 9.44),    // conv4_3
        (6.2, 4.2, 9.44),    // conv4_4
        (0.05, 1.05, 0.0),   // pool4
        (1.55, 1.05, 9.44),  // conv5_1 512@2x2
        (1.55, 1.05, 9.44),  // conv5_2
        (1.55, 1.05, 9.44),  // conv5_3
        (1.55, 1.05, 9.44),  // conv5_4
        (0.05, 0.26, 0.0),   // pool5
        (0.4, 0.26, 8.39),   // fc1 (512->4096 on 32x32 variant)
        (0.3, 0.26, 16.8),   // fc2
        (0.05, 0.005, 0.04), // fc3 -> 10
        (0.02, 0.005, 0.0),  // loss
    ];
    spec.iter()
        .map(|&(w, a, p)| LayerProfile { flops_weight: w, act_mb: a, param_mb: p })
        .collect()
}

impl ModelProfile {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn total_weight(&self) -> f64 {
        self.layers.iter().map(|l| l.flops_weight).sum()
    }

    /// Sum of compute weights over 1-based inclusive layer range [a, b].
    pub fn weight_range(&self, a: usize, b: usize) -> f64 {
        assert!(a >= 1 && b <= self.layers.len() && a <= b + 1, "bad layer range [{a},{b}]");
        self.layers[a - 1..b].iter().map(|l| l.flops_weight).sum()
    }

    /// Activation size (MB) emitted by 1-based layer `k` (batch 128).
    pub fn act_mb(&self, k: usize) -> f64 {
        self.layers[k - 1].act_mb
    }

    /// Parameter MB over 1-based inclusive range [a, b].
    pub fn param_mb_range(&self, a: usize, b: usize) -> f64 {
        self.layers[a - 1..b].iter().map(|l| l.param_mb).sum()
    }

    /// Helper-side memory footprint d_j (GB) for a client with cuts
    /// (σ1, σ2): part-2 params + optimizer state (x3) + stored activations
    /// of the part-2 layers (needed for bwd) + the input activation buffer.
    pub fn part2_footprint_gb(&self, cuts: (usize, usize)) -> f64 {
        let (s1, s2) = cuts;
        let params = self.param_mb_range(s1 + 1, s2);
        let acts: f64 = self.layers[s1..s2].iter().map(|l| l.act_mb).sum();
        let input = self.act_mb(s1);
        (3.0 * params + acts + input) / 1024.0
    }
}

/// Devices of the paper's testbed (Table I) plus their roles.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Device {
    RPi4,
    RPi3,
    JetsonNanoCpu,
    JetsonNanoGpu,
    Vm8Core,
    AppleM1,
}

/// Table I: measured batch-update (full model, batch 128) times in
/// seconds, per model, plus RAM.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub device: Device,
    pub name: &'static str,
    /// Full-model batch-update wall time (s): (ResNet101, VGG19).
    /// None = cannot train (RPi3 runs out of memory — it can still run
    /// *split* parts, which is the paper's point).
    pub batch_s: Option<(f64, f64)>,
    pub ram_gb: f64,
    /// Can act as a helper in the paper's setup (VM, M1).
    pub helper_capable: bool,
}

pub const DEVICES: [DeviceProfile; 6] = [
    DeviceProfile { device: Device::RPi4, name: "RPi 4B (Cortex-A72)", batch_s: Some((91.9, 71.9)), ram_gb: 4.0, helper_capable: false },
    // RPi3 cannot train the full model; for split parts we extrapolate its
    // speed as ~2.6x slower than RPi4 (A53@1.4GHz vs A72@1.5GHz, 1GB RAM).
    DeviceProfile { device: Device::RPi3, name: "RPi 3B+ (Cortex-A53)", batch_s: None, ram_gb: 1.0, helper_capable: false },
    DeviceProfile { device: Device::JetsonNanoCpu, name: "Jetson Nano (CPU)", batch_s: Some((143.0, 396.0)), ram_gb: 4.0, helper_capable: false },
    DeviceProfile { device: Device::JetsonNanoGpu, name: "Jetson Nano (GPU)", batch_s: Some((1.2, 2.6)), ram_gb: 4.0, helper_capable: false },
    DeviceProfile { device: Device::Vm8Core, name: "VM 8-core vCPU", batch_s: Some((2.0, 3.6)), ram_gb: 16.0, helper_capable: true },
    DeviceProfile { device: Device::AppleM1, name: "Apple M1 8-core", batch_s: Some((3.5, 3.6)), ram_gb: 16.0, helper_capable: true },
];

impl Device {
    pub fn profile(self) -> &'static DeviceProfile {
        DEVICES.iter().find(|d| d.device == self).unwrap()
    }

    /// Whole-model batch time (ms) for `model`; extrapolated for RPi3.
    pub fn batch_ms(self, model: Model) -> f64 {
        let p = self.profile();
        let (r, v) = match p.batch_s {
            Some(t) => t,
            // RPi3 extrapolation (see DeviceProfile comment).
            None => {
                let rpi4 = Device::RPi4.profile().batch_s.unwrap();
                (rpi4.0 * 2.6, rpi4.1 * 2.6)
            }
        };
        1000.0 * match model {
            Model::ResNet101 => r,
            Model::Vgg19 => v,
        }
    }

    /// Compute time (ms) to process (fwd+bwd) the 1-based layer range
    /// [a, b] of `model` on this device: whole-batch time scaled by the
    /// range's share of total FLOPs weight.
    pub fn range_ms(self, model: Model, a: usize, b: usize) -> f64 {
        let prof = model.profile();
        self.batch_ms(model) * prof.weight_range(a, b) / prof.total_weight()
    }

    /// (fwd_ms, bwd_ms) split of `range_ms` using the model's fwd share.
    pub fn range_fwd_bwd_ms(self, model: Model, a: usize, b: usize) -> (f64, f64) {
        let total = self.range_ms(model, a, b);
        let f = model.profile().fwd_frac;
        (total * f, total * (1.0 - f))
    }

    /// Client-capable device pool (Scenario 1 draws clients uniformly).
    pub fn client_pool() -> &'static [Device] {
        &[Device::RPi4, Device::RPi3, Device::JetsonNanoCpu, Device::JetsonNanoGpu]
    }

    /// Helper-capable pool (VM and M1 in the paper).
    pub fn helper_pool() -> &'static [Device] {
        &[Device::Vm8Core, Device::AppleM1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_match_paper() {
        assert_eq!(Model::ResNet101.profile().n_layers(), 37);
        assert_eq!(Model::Vgg19.profile().n_layers(), 25);
    }

    #[test]
    fn default_cuts_in_range() {
        for m in [Model::ResNet101, Model::Vgg19] {
            let p = m.profile();
            let (s1, s2) = p.default_cuts;
            assert!(1 <= s1 && s1 < s2 && s2 < p.n_layers());
        }
    }

    #[test]
    fn weight_ranges_partition() {
        for m in [Model::ResNet101, Model::Vgg19] {
            let p = m.profile();
            let (s1, s2) = p.default_cuts;
            let total = p.weight_range(1, s1) + p.weight_range(s1 + 1, s2) + p.weight_range(s2 + 1, p.n_layers());
            assert!((total - p.total_weight()).abs() < 1e-9);
        }
    }

    #[test]
    fn part2_dominates_compute() {
        // The whole point of SL: the offloaded middle carries most FLOPs.
        for m in [Model::ResNet101, Model::Vgg19] {
            let p = m.profile();
            let (s1, s2) = p.default_cuts;
            let frac = p.weight_range(s1 + 1, s2) / p.total_weight();
            assert!(frac > 0.6, "{}: part-2 share {frac}", p.name);
        }
    }

    #[test]
    fn table1_times_embedded() {
        assert!((Device::RPi4.batch_ms(Model::ResNet101) - 91_900.0).abs() < 1.0);
        assert!((Device::Vm8Core.batch_ms(Model::Vgg19) - 3_600.0).abs() < 1.0);
        assert!((Device::JetsonNanoGpu.batch_ms(Model::ResNet101) - 1_200.0).abs() < 1.0);
    }

    #[test]
    fn rpi3_extrapolated_slower_than_rpi4() {
        assert!(Device::RPi3.batch_ms(Model::ResNet101) > Device::RPi4.batch_ms(Model::ResNet101));
    }

    #[test]
    fn helpers_much_faster_than_clients() {
        // Table I: VM/M1 are two orders of magnitude faster than RPis.
        let vm = Device::Vm8Core.batch_ms(Model::ResNet101);
        let rpi = Device::RPi4.batch_ms(Model::ResNet101);
        assert!(rpi / vm > 20.0);
    }

    #[test]
    fn footprint_positive_and_reasonable() {
        for m in [Model::ResNet101, Model::Vgg19] {
            let p = m.profile();
            let d = p.part2_footprint_gb(p.default_cuts);
            assert!(d > 0.1 && d < 16.0, "{}: d = {d} GB", p.name);
        }
    }

    #[test]
    fn fwd_bwd_split_sums() {
        let (f, b) = Device::RPi4.range_fwd_bwd_ms(Model::Vgg19, 1, 3);
        let total = Device::RPi4.range_ms(Model::Vgg19, 1, 3);
        assert!((f + b - total).abs() < 1e-9);
        assert!(b > f, "VGG bwd should dominate (Fig 5 asymmetry)");
    }
}
