//! Composable scenario generation for the parallel-SL system.
//!
//! The paper's two evaluation settings (§VII "Setup") are kept as named
//! presets of a composable [`ScenarioSpec`]:
//!
//! * **Scenario 1 (low heterogeneity)** — clients and helpers are drawn
//!   uniformly from the testbed's device types (Table I); memory = RAM;
//!   all clients share the same cut layers (ResNet101 → (3, 33), VGG19 →
//!   (3, 23)); links follow the Akamai-France model.
//! * **Scenario 2 (high heterogeneity)** — device speeds are *interpolated*
//!   between the profiled devices (log-space), memory varies per entity
//!   (upper-bounded by RAM, with a few very-low-memory helpers), clients
//!   use *randomly selected* cut layers, and links have a wider spread.
//!
//! A spec composes orthogonal axes — device-mix distribution
//! ([`DeviceMix`]), per-entity memory model ([`MemoryModel`]), link regime
//! ([`LinkRegime`]), cut-layer policy ([`CutPolicy`]), delay jitter and a
//! client-churn knob — so new workloads are one constructor away. Four
//! additional named families ship out of the box:
//!
//! * **s3-clustered** — clustered device tiers (a fleet of a few hardware
//!   generations) over cellular-like links;
//! * **s4-straggler-tail** — a mostly-uniform fleet with a heavy straggler
//!   tail and nonzero client churn (the MP-SL / wireless-SL regime);
//! * **s5-memory-starved** — random cuts + helpers with tight, varied
//!   memory: assignment feasibility is the binding constraint;
//! * **s6-mega-homogeneous** — a huge identical fleet over uniform links:
//!   the balanced-greedy end of the §VII strategy rule.
//!
//! Each generated instance is deterministic in `(scenario, model, J, I,
//! seed)` — every experiment records this tuple. The S1/S2 presets draw
//! from the RNG in exactly the seed generator's order, so historical
//! tuples reproduce byte-identical instances.

use super::network::LinkModel;
use super::profiles::{Device, Model, ModelProfile};
use super::InstanceMs;
use crate::util::rng::{fnv64 as fnv, Rng};

/// Named scenario family (the paper's §VII settings plus the grown ones).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    S1,
    S2,
    S3Clustered,
    S4StragglerTail,
    S5MemoryStarved,
    S6MegaHomogeneous,
    S7HelperBursts,
    S8FlashCrowd,
}

impl Scenario {
    /// Every named family, in canonical order (sweep grids iterate this).
    pub const ALL: [Scenario; 8] = [
        Scenario::S1,
        Scenario::S2,
        Scenario::S3Clustered,
        Scenario::S4StragglerTail,
        Scenario::S5MemoryStarved,
        Scenario::S6MegaHomogeneous,
        Scenario::S7HelperBursts,
        Scenario::S8FlashCrowd,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Scenario::S1 => "scenario1",
            Scenario::S2 => "scenario2",
            Scenario::S3Clustered => "s3-clustered",
            Scenario::S4StragglerTail => "s4-straggler-tail",
            Scenario::S5MemoryStarved => "s5-memory-starved",
            Scenario::S6MegaHomogeneous => "s6-mega-homogeneous",
            Scenario::S7HelperBursts => "s7-helper-bursts",
            Scenario::S8FlashCrowd => "s8-flash-crowd",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "1" | "s1" | "scenario1" => Some(Scenario::S1),
            "2" | "s2" | "scenario2" => Some(Scenario::S2),
            "3" | "s3" | "s3-clustered" | "clustered" => Some(Scenario::S3Clustered),
            "4" | "s4" | "s4-straggler-tail" | "straggler-tail" | "stragglers" => Some(Scenario::S4StragglerTail),
            "5" | "s5" | "s5-memory-starved" | "memory-starved" => Some(Scenario::S5MemoryStarved),
            "6" | "s6" | "s6-mega-homogeneous" | "mega-homogeneous" => Some(Scenario::S6MegaHomogeneous),
            "7" | "s7" | "s7-helper-bursts" | "helper-bursts" => Some(Scenario::S7HelperBursts),
            "8" | "s8" | "s8-flash-crowd" | "flash-crowd" => Some(Scenario::S8FlashCrowd),
            _ => None,
        }
    }

    /// The composable spec behind this named family.
    pub fn spec(self) -> ScenarioSpec {
        match self {
            Scenario::S1 => ScenarioSpec::s1(),
            Scenario::S2 => ScenarioSpec::s2(),
            Scenario::S3Clustered => ScenarioSpec::s3_clustered(),
            Scenario::S4StragglerTail => ScenarioSpec::s4_straggler_tail(),
            Scenario::S5MemoryStarved => ScenarioSpec::s5_memory_starved(),
            Scenario::S6MegaHomogeneous => ScenarioSpec::s6_mega_homogeneous(),
            Scenario::S7HelperBursts => ScenarioSpec::s7_helper_bursts(),
            Scenario::S8FlashCrowd => ScenarioSpec::s8_flash_crowd(),
        }
    }
}

/// How entity speeds (whole-model batch times) are drawn from a device
/// pool. Each variant documents its RNG draw count per entity — presets
/// must keep the seed generator's draw order.
#[derive(Clone, Debug, PartialEq)]
pub enum DeviceMix {
    /// Uniform draw from the concrete pool (Scenario 1). One draw/entity.
    Pool,
    /// Log-space interpolation across the pool's speed continuum, widened
    /// by `widen` on both ends (Scenario 2). One draw/entity.
    LogInterp { widen: f64 },
    /// Clustered hardware tiers along the pool's log-speed continuum:
    /// a tier is picked by `weights`, centered at `centers[t]` (fraction
    /// of the log range, 0 = fastest end), with lognormal spread
    /// `sigma_log` inside the tier. `weights.len() == centers.len()`.
    Tiers { weights: Vec<f64>, centers: Vec<f64>, sigma_log: f64 },
    /// Uniform pool draw, but with probability `tail_frac` the entity is a
    /// straggler running `slow_factor`× slower (heavy right tail).
    StragglerTail { tail_frac: f64, slow_factor: f64 },
    /// Every entity is the same pool device (index into the pool); no
    /// draws — the fully homogeneous limit.
    Fixed { index: usize },
}

/// (ln(min/widen), ln(max·widen)) over the pool's batch times.
fn log_bounds(pool: &[Device], model: Model, widen: f64) -> (f64, f64) {
    let times: Vec<f64> = pool.iter().map(|d| d.batch_ms(model)).collect();
    let lo = (times.iter().cloned().fold(f64::MAX, f64::min) / widen).ln();
    let hi = (times.iter().cloned().fold(0.0f64, f64::max) * widen).ln();
    (lo, hi)
}

impl DeviceMix {
    /// Draw one entity's whole-model batch time (ms).
    pub fn draw_batch_ms(&self, rng: &mut Rng, pool: &[Device], model: Model) -> f64 {
        match self {
            DeviceMix::Pool => rng.choice(pool).batch_ms(model),
            DeviceMix::LogInterp { widen } => {
                let (lo, hi) = log_bounds(pool, model, *widen);
                rng.range_f64(lo, hi).exp()
            }
            DeviceMix::Tiers { weights, centers, sigma_log } => {
                debug_assert_eq!(weights.len(), centers.len(), "tier tables must align");
                let (lo, hi) = log_bounds(pool, model, 1.0);
                let t = rng.weighted_choice(weights);
                let center = lo + centers[t].clamp(0.0, 1.0) * (hi - lo);
                (center + rng.normal(0.0, *sigma_log)).exp()
            }
            DeviceMix::StragglerTail { tail_frac, slow_factor } => {
                let base = rng.choice(pool).batch_ms(model);
                if rng.chance(*tail_frac) {
                    base * slow_factor
                } else {
                    base
                }
            }
            DeviceMix::Fixed { index } => pool[index % pool.len()].batch_ms(model),
        }
    }
}

/// Per-client cut-layer policy (σ1, σ2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CutPolicy {
    /// The model's default cuts for every client (Scenario 1); no draws.
    Default,
    /// Per-client random cuts, σ1 early / σ2 late (Scenario 2); two
    /// draws/client: σ1 early enough that part-1 stays cheap, σ2 near the
    /// end but leaving a real part-3.
    RandomWide,
    /// The same explicit cuts for every client; no draws.
    Fixed(usize, usize),
}

impl CutPolicy {
    fn draw(&self, rng: &mut Rng, prof: &ModelProfile) -> (usize, usize) {
        match *self {
            CutPolicy::Default => prof.default_cuts,
            CutPolicy::RandomWide => {
                let n_layers = prof.n_layers();
                let s1 = rng.range_usize(2, 5.min(n_layers / 3));
                let hi = n_layers - 2;
                let lo = (n_layers * 2 / 3).max(s1 + 2).min(hi);
                let s2 = rng.range_usize(lo, hi);
                (s1, s2)
            }
            CutPolicy::Fixed(a, b) => (a, b),
        }
    }
}

/// Per-helper memory-capacity model (as a function of the backing
/// device's RAM).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemoryModel {
    /// Capacity = the device's full RAM (Scenario 1); no draws.
    FullRam,
    /// Uniform in [lo·RAM, hi·RAM] (Scenario 2 uses lo=0.15, hi=1.0:
    /// "can vary from device to device, upper-bounded by RAM"); one
    /// draw/helper.
    UniformFraction { lo: f64, hi: f64 },
}

impl MemoryModel {
    fn draw(&self, rng: &mut Rng, ram_gb: f64) -> f64 {
        match *self {
            MemoryModel::FullRam => ram_gb,
            MemoryModel::UniformFraction { lo, hi } => rng.range_f64(lo * ram_gb, hi * ram_gb),
        }
    }
}

/// Link-rate regime for the client↔helper bipartite network.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkRegime {
    /// Akamai State-of-the-Internet France Q4'16 (Scenario 1).
    AkamaiFrance,
    /// Wider spread with a slower tail (Scenario 2).
    WideSpread,
    /// Cellular-like: lower median, longer RTT overhead.
    CellularLike,
    /// Every link at exactly `mbps` (homogeneous limit).
    UniformFixed { mbps: f64 },
}

impl LinkRegime {
    pub fn model(self) -> LinkModel {
        match self {
            LinkRegime::AkamaiFrance => LinkModel::france_q4_2016(),
            LinkRegime::WideSpread => LinkModel::heterogeneous(),
            LinkRegime::CellularLike => LinkModel::cellular(),
            LinkRegime::UniformFixed { mbps } => LinkModel::uniform(mbps),
        }
    }
}

/// A composable scenario: who the devices are, how much memory helpers
/// have, what the links look like, where the cuts go, how noisy the
/// delays are, and how flaky the clients are.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Family name; mixed into the RNG seed and recorded in every
    /// instance label (presets keep the seed generator's names so
    /// historical tuples reproduce).
    pub name: String,
    pub client_mix: DeviceMix,
    pub helper_mix: DeviceMix,
    pub cut_policy: CutPolicy,
    pub memory: MemoryModel,
    pub link: LinkRegime,
    /// Multiplicative jitter (lognormal σ) applied to every profiled time.
    pub jitter_sigma: f64,
    /// Per-round probability that a client drops out (consumed by
    /// [`ScenarioCfg::generate_rounds`]; `generate` ignores it).
    pub churn: f64,
    /// When true, memory repair additionally guarantees *wedge-free
    /// sequential packing*: total capacity ≥ total demand + I·max_d, which
    /// makes **any** sequential feasible-choice assignment (balanced
    /// greedy, the random baseline, ADMM's y-subproblem) succeed
    /// unconditionally. The legacy presets keep the seed generator's
    /// weaker aggregate-slack repair so historical `(scenario, model, J,
    /// I, seed)` tuples stay byte-identical.
    pub packable: bool,
}

impl ScenarioSpec {
    /// Paper Scenario 1 (low heterogeneity).
    pub fn s1() -> ScenarioSpec {
        ScenarioSpec {
            name: "scenario1".to_string(),
            client_mix: DeviceMix::Pool,
            helper_mix: DeviceMix::Pool,
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::AkamaiFrance,
            jitter_sigma: 0.08,
            churn: 0.0,
            packable: false,
        }
    }

    /// Paper Scenario 2 (high heterogeneity). The helper pool (VM, M1)
    /// spans a narrow 2–3.6 s band, so helper speeds widen the continuum
    /// by 2× on both ends — S2 must be *more* heterogeneous than S1's two
    /// fixed helper types (§VII explicitly has "a few helpers with very
    /// limited" capabilities).
    pub fn s2() -> ScenarioSpec {
        ScenarioSpec {
            name: "scenario2".to_string(),
            client_mix: DeviceMix::LogInterp { widen: 1.0 },
            helper_mix: DeviceMix::LogInterp { widen: 2.0 },
            cut_policy: CutPolicy::RandomWide,
            memory: MemoryModel::UniformFraction { lo: 0.15, hi: 1.0 },
            link: LinkRegime::WideSpread,
            jitter_sigma: 0.15,
            churn: 0.0,
            packable: false,
        }
    }

    /// Clustered hardware generations over cellular-like links: half the
    /// fleet is slow, a third mid-range, a sixth fast.
    pub fn s3_clustered() -> ScenarioSpec {
        ScenarioSpec {
            name: "s3-clustered".to_string(),
            client_mix: DeviceMix::Tiers {
                weights: vec![0.5, 0.35, 0.15],
                centers: vec![0.85, 0.5, 0.1],
                sigma_log: 0.06,
            },
            helper_mix: DeviceMix::Tiers {
                weights: vec![0.6, 0.4],
                centers: vec![0.3, 0.8],
                sigma_log: 0.05,
            },
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::CellularLike,
            jitter_sigma: 0.10,
            churn: 0.0,
            packable: true,
        }
    }

    /// Mostly-uniform fleet with a heavy straggler tail and client churn.
    pub fn s4_straggler_tail() -> ScenarioSpec {
        ScenarioSpec {
            name: "s4-straggler-tail".to_string(),
            client_mix: DeviceMix::StragglerTail { tail_frac: 0.12, slow_factor: 8.0 },
            helper_mix: DeviceMix::StragglerTail { tail_frac: 0.08, slow_factor: 4.0 },
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::AkamaiFrance,
            jitter_sigma: 0.10,
            churn: 0.15,
            packable: true,
        }
    }

    /// Tight, varied helper memory with per-client random cuts: the
    /// assignment-feasibility stress family.
    pub fn s5_memory_starved() -> ScenarioSpec {
        ScenarioSpec {
            name: "s5-memory-starved".to_string(),
            client_mix: DeviceMix::Pool,
            helper_mix: DeviceMix::Pool,
            cut_policy: CutPolicy::RandomWide,
            memory: MemoryModel::UniformFraction { lo: 0.06, hi: 0.30 },
            link: LinkRegime::AkamaiFrance,
            jitter_sigma: 0.08,
            churn: 0.0,
            packable: true,
        }
    }

    /// Helper-fault stress family: an s1-like client fleet whose
    /// *helpers* blink. The fleet orchestrator pairs this family with
    /// transient helper-outage bursts
    /// ([`HelperChurnCfg::bursts`](crate::fleet::events::HelperChurnCfg::bursts));
    /// the client side stays mild so degraded rounds isolate the
    /// helper-loss effect. Packable, so repair keeps its wedge-free
    /// guarantee on the surviving helpers.
    pub fn s7_helper_bursts() -> ScenarioSpec {
        ScenarioSpec {
            name: "s7-helper-bursts".to_string(),
            client_mix: DeviceMix::Pool,
            helper_mix: DeviceMix::Pool,
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::AkamaiFrance,
            jitter_sigma: 0.10,
            churn: 0.10,
            packable: true,
        }
    }

    /// Flash-crowd stress family: a cellular client fleet with stationary
    /// churn whose *arrival* stream spikes in periodic bursts. The fleet
    /// orchestrator pairs this family with burst arrival multipliers
    /// ([`FlashCrowdCfg`](crate::fleet::events::FlashCrowdCfg)) seeded on
    /// the existing client-event stream; the per-instance spec stays mild
    /// so spike rounds isolate the arrival-surge effect. Cellular-like
    /// links make it the natural companion to the shared-uplink transport
    /// model (flash crowds contend for the same pools they flood).
    /// Packable, so repair survives arrival surges up to `max_clients`.
    pub fn s8_flash_crowd() -> ScenarioSpec {
        ScenarioSpec {
            name: "s8-flash-crowd".to_string(),
            client_mix: DeviceMix::Pool,
            helper_mix: DeviceMix::Pool,
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::CellularLike,
            jitter_sigma: 0.10,
            churn: 0.10,
            packable: true,
        }
    }

    /// A huge identical fleet over uniform links: the balanced-greedy end
    /// of the §VII strategy rule.
    pub fn s6_mega_homogeneous() -> ScenarioSpec {
        ScenarioSpec {
            name: "s6-mega-homogeneous".to_string(),
            client_mix: DeviceMix::Fixed { index: 0 },
            helper_mix: DeviceMix::Fixed { index: 0 },
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::UniformFixed { mbps: 12.0 },
            jitter_sigma: 0.02,
            churn: 0.0,
            packable: true,
        }
    }

    // ---- builder-style composition --------------------------------------

    pub fn named(mut self, name: &str) -> ScenarioSpec {
        self.name = name.to_string();
        self
    }
    pub fn with_link(mut self, link: LinkRegime) -> ScenarioSpec {
        self.link = link;
        self
    }
    pub fn with_memory(mut self, memory: MemoryModel) -> ScenarioSpec {
        self.memory = memory;
        self
    }
    pub fn with_cuts(mut self, cut_policy: CutPolicy) -> ScenarioSpec {
        self.cut_policy = cut_policy;
        self
    }
    pub fn with_client_mix(mut self, mix: DeviceMix) -> ScenarioSpec {
        self.client_mix = mix;
        self
    }
    pub fn with_helper_mix(mut self, mix: DeviceMix) -> ScenarioSpec {
        self.helper_mix = mix;
        self
    }
    pub fn with_jitter(mut self, sigma: f64) -> ScenarioSpec {
        self.jitter_sigma = sigma;
        self
    }
    pub fn with_churn(mut self, p: f64) -> ScenarioSpec {
        self.churn = p;
        self
    }
    pub fn with_packable(mut self, packable: bool) -> ScenarioSpec {
        self.packable = packable;
        self
    }
}

/// Generator configuration: a spec plus the experiment tuple.
#[derive(Clone, Debug)]
pub struct ScenarioCfg {
    pub spec: ScenarioSpec,
    pub model: Model,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    /// Activation wire-size factor: fraction of the raw fp32 activation
    /// tensor actually shipped (fp16 + activation compression on the
    /// testbed). Calibrated so horizons land near the paper's reported
    /// range (T≈294 for ResNet101 J=10 at |S_t|=180ms; T≈176 for VGG19
    /// at 550ms) — see DESIGN.md substitution table.
    pub wire_factor: f64,
    /// Per-helper preemption switching cost, ms (0 = paper's base model).
    pub switch_cost_ms: f64,
}

impl ScenarioCfg {
    pub fn new(scenario: Scenario, model: Model, n_clients: usize, n_helpers: usize, seed: u64) -> Self {
        Self::from_spec(scenario.spec(), model, n_clients, n_helpers, seed)
    }

    /// Build from a custom composed spec.
    pub fn from_spec(spec: ScenarioSpec, model: Model, n_clients: usize, n_helpers: usize, seed: u64) -> Self {
        ScenarioCfg {
            spec,
            model,
            n_clients,
            n_helpers,
            seed,
            wire_factor: 0.10,
            switch_cost_ms: 0.0,
        }
    }

    pub fn with_switch_cost(mut self, ms: f64) -> Self {
        self.switch_cost_ms = ms;
        self
    }

    /// Generate the instance.
    pub fn generate(&self) -> InstanceMs {
        let mut rng = Rng::seeded(self.seed ^ fnv(&self.spec.name) ^ fnv(self.model.name()));
        let prof = self.model.profile();
        let (j_n, i_n) = (self.n_clients, self.n_helpers);

        // --- per-client cut layers -------------------------------------
        let cuts: Vec<(usize, usize)> = (0..j_n).map(|_| self.spec.cut_policy.draw(&mut rng, &prof)).collect();

        // --- device speed factors ---------------------------------------
        // For each entity we derive a whole-model batch time (ms) from the
        // spec's device mix over the role's pool.
        let client_pool = Device::client_pool();
        let helper_pool = Device::helper_pool();
        let model = self.model;
        let client_batch_ms: Vec<f64> = (0..j_n)
            .map(|_| self.spec.client_mix.draw_batch_ms(&mut rng, client_pool, model))
            .collect();
        let helper_batch_ms: Vec<f64> = (0..i_n)
            .map(|_| self.spec.helper_mix.draw_batch_ms(&mut rng, helper_pool, model))
            .collect();

        // --- memory -------------------------------------------------------
        let d_gb: Vec<f64> = cuts.iter().map(|&c| prof.part2_footprint_gb(c)).collect();
        let helper_ram: Vec<f64> = (0..i_n)
            .map(|k| {
                let ram = helper_pool[k % helper_pool.len()].profile().ram_gb;
                self.spec.memory.draw(&mut rng, ram)
            })
            .collect();
        let mem_gb = if self.spec.packable {
            repair_memory_packable(&d_gb, helper_ram)
        } else {
            repair_memory(&d_gb, helper_ram)
        };

        // --- links ---------------------------------------------------------
        let link = self.spec.link.model();
        let rates = link.draw_rates(&mut rng, i_n, j_n);

        // --- per-edge delay vectors ----------------------------------------
        let e_n = i_n * j_n;
        let (mut r_ms, mut l_ms, mut lp_ms, mut rp_ms, mut p_ms, mut pp_ms) = (
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
        );
        for j in 0..j_n {
            let dm = ClientDelayModel::new(&prof, cuts[j], client_batch_ms[j], self.wire_factor);
            for i in 0..i_n {
                let e = i * j_n + j;
                let d = dm.draw_edge(&mut rng, &link, helper_batch_ms[i], rates[e], self.spec.jitter_sigma);
                r_ms[e] = d[0];
                l_ms[e] = d[1];
                lp_ms[e] = d[2];
                rp_ms[e] = d[3];
                p_ms[e] = d[4];
                pp_ms[e] = d[5];
            }
        }

        let inst = InstanceMs {
            n_clients: j_n,
            n_helpers: i_n,
            r_ms,
            l_ms,
            lp_ms,
            rp_ms,
            p_ms,
            pp_ms,
            d_gb,
            mem_gb,
            mu_ms: vec![self.switch_cost_ms; i_n],
            label: format!(
                "{}/{} J={} I={} seed={}",
                self.spec.name,
                self.model.name(),
                j_n,
                i_n,
                self.seed
            ),
        };
        inst.validate().expect("generator produced invalid instance");
        inst
    }

    /// Generate a churn round sequence: the base instance projected onto
    /// the clients that stayed for each round. With `spec.churn == 0`
    /// every round is the full instance. Deterministic in the tuple —
    /// the churn stream is derived from the same seed, independent of the
    /// instance stream.
    pub fn generate_rounds(&self, rounds: usize) -> Vec<InstanceMs> {
        let base = self.generate();
        if self.spec.churn <= 0.0 || base.n_clients <= 1 {
            return vec![base; rounds];
        }
        let mut rng = Rng::seeded(self.seed ^ fnv(&self.spec.name) ^ fnv("churn"));
        (0..rounds)
            .map(|round| {
                let mut keep: Vec<usize> = (0..base.n_clients).filter(|_| !rng.chance(self.spec.churn)).collect();
                if keep.is_empty() {
                    keep.push(rng.below(base.n_clients));
                }
                let mut inst = base.restrict_clients(&keep);
                inst.label = format!("{} round={round} J'={}", base.label, keep.len());
                inst
            })
            .collect()
    }
}

/// Per-client parameters of the §III delay model — the ONE copy shared by
/// the batch generator ([`ScenarioCfg::generate`]) and the fleet client
/// factory ([`FleetWorld::mint_client`]), so minted arrivals can never
/// drift from base-scenario instances. Construction does no RNG draws;
/// [`ClientDelayModel::draw_edge`] performs exactly the seed generator's
/// six jitter draws per edge, in its order.
struct ClientDelayModel {
    /// Client part-1 fwd / bwd compute (ms).
    p1_f: f64,
    p1_b: f64,
    /// Client part-3 fwd / bwd compute (ms).
    p3_f: f64,
    p3_b: f64,
    /// Wire sizes (MB): activations at σ1 and σ2 (grad ≈ act size).
    a1_mb: f64,
    a2_mb: f64,
    /// Part-2 weight share (scales the helper's whole-batch time).
    part2_share: f64,
    fwd_frac: f64,
}

impl ClientDelayModel {
    fn new(prof: &ModelProfile, cut: (usize, usize), batch_ms: f64, wire_factor: f64) -> ClientDelayModel {
        let n_layers = prof.n_layers();
        let total_w = prof.total_weight();
        // Whole-batch time scaled by part share, then split fwd/bwd by
        // the model's fwd fraction.
        let share = |a: usize, b: usize| if a > b { 0.0 } else { prof.weight_range(a, b) / total_w };
        let f = prof.fwd_frac;
        let (s1, s2) = cut;
        let part1 = batch_ms * share(1, s1);
        let part3 = batch_ms * share(s2 + 1, n_layers);
        ClientDelayModel {
            p1_f: part1 * f,
            p1_b: part1 * (1.0 - f),
            p3_f: part3 * f,
            p3_b: part3 * (1.0 - f),
            a1_mb: prof.act_mb(s1) * wire_factor,
            a2_mb: prof.act_mb(s2) * wire_factor,
            part2_share: share(s1 + 1, s2),
            fwd_frac: f,
        }
    }

    /// Draw one (helper, client) edge's six delay entries
    /// (r, l, l', r', p, p'), in the seed generator's draw order.
    fn draw_edge(&self, rng: &mut Rng, link: &LinkModel, helper_batch_ms: f64, rate: f64, sigma: f64) -> [f64; 6] {
        let up1 = link.transfer_ms(self.a1_mb, rate);
        let dn2 = link.transfer_ms(self.a2_mb, rate);
        let up2 = link.transfer_ms(self.a2_mb, rate);
        let dn1 = link.transfer_ms(self.a1_mb, rate);
        let part2 = helper_batch_ms * self.part2_share;
        let f = self.fwd_frac;
        [
            rng.lognormal_median(self.p1_f + up1, sigma),
            rng.lognormal_median(dn2 + self.p3_f, sigma),
            rng.lognormal_median(self.p3_b + up2, sigma),
            rng.lognormal_median(dn1 + self.p1_b, sigma),
            rng.lognormal_median((part2 * f).max(1.0), sigma),
            rng.lognormal_median((part2 * (1.0 - f)).max(1.0), sigma),
        ]
    }
}

// ---- fleet world: persistent helpers + a stable-id client factory -------

/// One fleet client minted by a [`FleetWorld`]: its stable id, the draws
/// that define it (cut layers, whole-model batch time, per-helper link
/// rates) and the fully materialized per-helper delay columns. A client's
/// draws depend only on `(scenario tuple, id)` — never on when it arrives
/// or who else is in the fleet — so multi-round rosters stay reproducible
/// under any churn history.
#[derive(Clone, Debug)]
pub struct FleetClient {
    /// Stable fleet-wide id (base clients are `0..J`; arrivals continue
    /// the sequence and ids are never reused).
    pub id: u64,
    pub cut: (usize, usize),
    /// Whole-model batch time drawn from the spec's client [`DeviceMix`].
    pub batch_ms: f64,
    /// Helper-memory footprint (GB), capped at the world's admission
    /// limit [`FleetWorld::d_cap`].
    pub d_gb: f64,
    /// Symmetric link rate to each helper (Mbps), drawn from the spec's
    /// [`LinkRegime`].
    pub rates_mbps: Vec<f64>,
    /// Per-helper delay columns (len = `n_helpers` each), same semantics
    /// as the corresponding [`InstanceMs`] vectors.
    pub r_ms: Vec<f64>,
    pub l_ms: Vec<f64>,
    pub lp_ms: Vec<f64>,
    pub rp_ms: Vec<f64>,
    pub p_ms: Vec<f64>,
    pub pp_ms: Vec<f64>,
}

/// One fleet helper minted by a [`FleetWorld`]: its stable id, its
/// whole-model batch time and its memory capacity. Base helpers
/// (`id < I`) carry the world's stored draws; joined helpers (dynamic
/// worlds only) reproduce from `(scenario tuple, id)` alone, like
/// clients.
#[derive(Clone, Debug)]
pub struct FleetHelper {
    /// Stable fleet-wide id (base helpers are `0..I`; joins continue the
    /// sequence and ids are never reused).
    pub id: u64,
    /// Whole-model batch time drawn from the spec's helper [`DeviceMix`].
    pub batch_ms: f64,
    /// Memory capacity (GB). In dynamic worlds this is floored to the
    /// outage-proof level [`FleetWorld::helper_mem_floor`].
    pub mem_gb: f64,
}

/// A persistent multi-round fleet: fixed helpers (speeds, memory, switch
/// costs) plus a deterministic client factory. Where [`ScenarioCfg::
/// generate`] draws one closed instance, a world mints clients *by stable
/// id* from the same spec distributions, so clients can arrive and depart
/// between rounds while every minted client reproduces byte-identically
/// from the `(scenario, model, J, I, seed, id)` tuple alone.
///
/// [`ScenarioCfg::fleet_world_dynamic`] builds a *dynamic* world whose
/// helper roster may change at runtime (outages, joins): every helper is
/// provisioned to host the whole roster alone, so any non-empty
/// surviving subset keeps repair memory-feasible, and joined helpers
/// mint from per-id streams ([`FleetWorld::mint_helper`]).
#[derive(Clone, Debug)]
pub struct FleetWorld {
    cfg: ScenarioCfg,
    link: LinkModel,
    helper_batch_ms: Vec<f64>,
    /// Helper memory capacities (GB), repaired once so that **any** roster
    /// of at most `max_clients` admitted clients packs wedge-free:
    /// total capacity ≥ (max_clients + I)·d_cap, hence at every point of
    /// any incremental placement some helper has free ≥ d_cap ≥ d_j.
    pub mem_gb: Vec<f64>,
    /// Admission footprint cap: the largest raw footprint over the base
    /// population. Arrivals requesting more are admitted at this cap (the
    /// orchestrator's admission policy), keeping the wedge-free guarantee
    /// independent of the cut-draw tail.
    pub d_cap: f64,
    /// Roster-size cap the memory repair was sized for.
    pub max_clients: usize,
    /// True when the helper roster may change at runtime (built by
    /// [`ScenarioCfg::fleet_world_dynamic`]).
    helper_dynamic: bool,
    /// Outage-proof per-helper capacity floor for dynamic worlds:
    /// `(max_clients + 1)·d_cap·1.001`, so a *single* surviving helper
    /// can host the entire admitted roster and helper loss can never
    /// wedge the repair. `0.0` in static worlds.
    pub helper_mem_floor: f64,
}

impl ScenarioCfg {
    /// Build the persistent fleet world behind this tuple. `max_clients`
    /// bounds the roster size the world's memory repair must support (the
    /// churn process enforces the same cap on arrivals).
    pub fn fleet_world(&self, max_clients: usize) -> FleetWorld {
        self.fleet_world_impl(max_clients, false)
    }

    /// Build a *dynamic* fleet world: same draws as [`fleet_world`], but
    /// every base helper's capacity is floored to the outage-proof level
    /// `(max_clients + 1)·d_cap·1.001` — any single surviving helper can
    /// host the whole admitted roster, so no sequence of helper outages
    /// can make the memory repair infeasible. Joined helpers mint at the
    /// same floor. Static runs keep [`fleet_world`]'s exact bytes, so
    /// enabling helper dynamics never perturbs helper-free artifacts.
    ///
    /// [`fleet_world`]: ScenarioCfg::fleet_world
    pub fn fleet_world_dynamic(&self, max_clients: usize) -> FleetWorld {
        self.fleet_world_impl(max_clients, true)
    }

    fn fleet_world_impl(&self, max_clients: usize, dynamic: bool) -> FleetWorld {
        // A helper-less world can never place anyone: the wedge-free
        // guarantee below (and every repair built on it) assumes I ≥ 1,
        // so reject the configuration here instead of letting repair
        // misreport each round as full-infeasible.
        assert!(self.n_helpers >= 1, "fleet worlds require at least one helper (I >= 1), got I = 0");
        let max_clients = max_clients.max(self.n_clients).max(1);
        let mut rng = Rng::seeded(
            self.seed ^ fnv(&self.spec.name) ^ fnv(self.model.name()).rotate_left(13) ^ fnv("fleet-helpers"),
        );
        let helper_pool = Device::helper_pool();
        let i_n = self.n_helpers;
        let helper_batch_ms: Vec<f64> = (0..i_n)
            .map(|_| self.spec.helper_mix.draw_batch_ms(&mut rng, helper_pool, self.model))
            .collect();
        let helper_ram: Vec<f64> = (0..i_n)
            .map(|k| {
                let ram = helper_pool[k % helper_pool.len()].profile().ram_gb;
                self.spec.memory.draw(&mut rng, ram)
            })
            .collect();
        let mut world = FleetWorld {
            cfg: self.clone(),
            link: self.spec.link.model(),
            helper_batch_ms,
            mem_gb: helper_ram,
            d_cap: f64::MAX,
            max_clients,
            helper_dynamic: dynamic,
            helper_mem_floor: 0.0,
        };
        // Admission cap = the largest raw footprint over the base
        // population (ids 0..J). Minting with d_cap = MAX leaves base
        // footprints unclamped.
        let d_cap = (0..self.n_clients as u64)
            .map(|id| world.mint_client(id).d_gb)
            .fold(0.0f64, f64::max)
            .max(self.model.profile().part2_footprint_gb(self.model.profile().default_cuts));
        world.d_cap = d_cap;
        // Wedge-free repair for every roster up to max_clients (cf.
        // `repair_memory_packable`): placed ≤ max_clients·d_cap at any
        // point, so free ≥ I·d_cap and some helper fits any admitted d.
        let need = (max_clients + i_n) as f64 * d_cap;
        let cap: f64 = world.mem_gb.iter().sum();
        if cap < need {
            let scale = need / cap.max(1e-9) * 1.001;
            for m in &mut world.mem_gb {
                *m *= scale;
            }
        }
        let max_m = world.mem_gb.iter().cloned().fold(0.0, f64::max);
        if max_m < d_cap {
            let k = world
                .mem_gb
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            world.mem_gb[k] = d_cap * 1.05;
        }
        if dynamic {
            // Outage-proof floor: the sum-based wedge-free guarantee
            // above breaks the moment a helper goes down, so dynamic
            // worlds provision each helper to host the whole roster
            // alone. The floor subsumes both repairs (per-helper ≥
            // (max_clients + 1)·d_cap implies the sum bound for any
            // non-empty subset).
            world.helper_mem_floor = (max_clients + 1) as f64 * d_cap * 1.001;
            for m in &mut world.mem_gb {
                *m = m.max(world.helper_mem_floor);
            }
        }
        world
    }
}

impl FleetWorld {
    pub fn n_helpers(&self) -> usize {
        self.cfg.n_helpers
    }

    pub fn base_clients(&self) -> usize {
        self.cfg.n_clients
    }

    /// True when this world supports a runtime-changing helper roster
    /// (built by [`ScenarioCfg::fleet_world_dynamic`]).
    pub fn helper_modeled(&self) -> bool {
        self.helper_dynamic
    }

    /// The client's private draw stream: a pure function of the scenario
    /// tuple and the stable id (mirrors `bench::sweep::cell_seed`'s
    /// label-mixing idiom).
    fn client_seed(&self, id: u64) -> u64 {
        self.cfg.seed
            ^ fnv(&self.cfg.spec.name)
            ^ fnv(self.cfg.model.name()).rotate_left(13)
            ^ fnv("fleet-client").rotate_left(29)
            ^ (id + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Mint the client with stable id `id`: cut draw, device-mix batch
    /// time, per-helper link rates and jittered delay columns, all from
    /// the client's private stream.
    pub fn mint_client(&self, id: u64) -> FleetClient {
        let mut rng = Rng::seeded(self.client_seed(id));
        let spec = &self.cfg.spec;
        let prof = self.cfg.model.profile();
        let i_n = self.cfg.n_helpers;
        let cut = spec.cut_policy.draw(&mut rng, &prof);
        let batch_ms = spec.client_mix.draw_batch_ms(&mut rng, Device::client_pool(), self.cfg.model);
        let d_gb = prof.part2_footprint_gb(cut).min(self.d_cap);
        let rates_mbps: Vec<f64> = (0..i_n).map(|_| self.link.draw_rate(&mut rng)).collect();

        let dm = ClientDelayModel::new(&prof, cut, batch_ms, self.cfg.wire_factor);
        let (mut r_ms, mut l_ms, mut lp_ms, mut rp_ms, mut p_ms, mut pp_ms) = (
            vec![0.0; i_n],
            vec![0.0; i_n],
            vec![0.0; i_n],
            vec![0.0; i_n],
            vec![0.0; i_n],
            vec![0.0; i_n],
        );
        for i in 0..i_n {
            let d = dm.draw_edge(&mut rng, &self.link, self.helper_batch_ms[i], rates_mbps[i], spec.jitter_sigma);
            r_ms[i] = d[0];
            l_ms[i] = d[1];
            lp_ms[i] = d[2];
            rp_ms[i] = d[3];
            p_ms[i] = d[4];
            pp_ms[i] = d[5];
        }
        FleetClient { id, cut, batch_ms, d_gb, rates_mbps, r_ms, l_ms, lp_ms, rp_ms, p_ms, pp_ms }
    }

    /// A joined helper's private draw stream (same label-mixing idiom as
    /// [`FleetWorld::mint_client`]'s).
    fn helper_seed(&self, id: u64) -> u64 {
        self.cfg.seed
            ^ fnv(&self.cfg.spec.name)
            ^ fnv(self.cfg.model.name()).rotate_left(13)
            ^ fnv("fleet-helper-join").rotate_left(29)
            ^ (id + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// A (client, joined helper) edge's private draw stream: pure in the
    /// scenario tuple and both stable ids, so extension columns never
    /// depend on when the helper joined or who else is in the fleet.
    fn edge_seed(&self, client_id: u64, helper_id: u64) -> u64 {
        self.client_seed(client_id)
            ^ fnv("fleet-helper-edge").rotate_left(17)
            ^ (helper_id + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Mint the helper with stable id `id`. Base helpers (`id < I`)
    /// return the world's stored draws; joined helpers (dynamic worlds
    /// only) draw batch time and memory from the spec's helper
    /// distributions on a private per-id stream, with memory floored to
    /// the outage-proof level.
    pub fn mint_helper(&self, id: u64) -> FleetHelper {
        if (id as usize) < self.cfg.n_helpers {
            return FleetHelper {
                id,
                batch_ms: self.helper_batch_ms[id as usize],
                mem_gb: self.mem_gb[id as usize],
            };
        }
        assert!(
            self.helper_dynamic,
            "joined helpers require a dynamic world (ScenarioCfg::fleet_world_dynamic)"
        );
        let mut rng = Rng::seeded(self.helper_seed(id));
        let pool = Device::helper_pool();
        let batch_ms = self.cfg.spec.helper_mix.draw_batch_ms(&mut rng, pool, self.cfg.model);
        let ram = pool[id as usize % pool.len()].profile().ram_gb;
        let mem_gb = self.cfg.spec.memory.draw(&mut rng, ram).max(self.helper_mem_floor);
        FleetHelper { id, batch_ms, mem_gb }
    }

    /// Assemble the instance for a roster of minted clients (columns in
    /// roster order; callers keep rosters sorted by id for canonical
    /// layouts). Accepts owned clients or references (the orchestrator
    /// passes `&[&FleetClient]` straight out of its mint cache). An empty
    /// roster yields a valid empty instance — full-departure rounds must
    /// not abort a fleet run.
    pub fn instance<C: std::borrow::Borrow<FleetClient>>(&self, roster: &[C]) -> InstanceMs {
        let j_n = roster.len();
        let i_n = self.cfg.n_helpers;
        let e_n = i_n * j_n;
        let collect = |col: fn(&FleetClient) -> &Vec<f64>| -> Vec<f64> {
            let mut out = Vec::with_capacity(e_n);
            for i in 0..i_n {
                for c in roster {
                    out.push(col(c.borrow())[i]);
                }
            }
            out
        };
        let inst = InstanceMs {
            n_clients: j_n,
            n_helpers: i_n,
            r_ms: collect(|c| &c.r_ms),
            l_ms: collect(|c| &c.l_ms),
            lp_ms: collect(|c| &c.lp_ms),
            rp_ms: collect(|c| &c.rp_ms),
            p_ms: collect(|c| &c.p_ms),
            pp_ms: collect(|c| &c.pp_ms),
            d_gb: roster
                .iter()
                .map(|c| {
                    let c: &FleetClient = c.borrow();
                    c.d_gb
                })
                .collect(),
            mem_gb: self.mem_gb.clone(),
            mu_ms: vec![self.cfg.switch_cost_ms; i_n],
            label: format!(
                "fleet:{}/{} J={} I={} seed={}",
                self.cfg.spec.name,
                self.cfg.model.name(),
                j_n,
                i_n,
                self.cfg.seed
            ),
        };
        inst.validate().expect("fleet world produced invalid instance");
        inst
    }

    /// Assemble the instance for a roster of minted clients on an
    /// explicit helper set (sorted by id). With exactly the base helper
    /// set this delegates to [`FleetWorld::instance`] and is
    /// byte-identical to it; with a changed set (outages, joins) the
    /// clients' cached base columns are reused for base helpers and
    /// joined-helper columns are drawn on the fly from pure per-edge
    /// streams ([`FleetWorld::edge_seed`]), so the instance is a pure
    /// function of `(scenario tuple, roster ids, helper ids)`.
    pub fn instance_on<C: std::borrow::Borrow<FleetClient>>(
        &self,
        roster: &[C],
        helpers: &[FleetHelper],
    ) -> InstanceMs {
        let base_i = self.cfg.n_helpers;
        if helpers.len() == base_i && helpers.iter().enumerate().all(|(k, h)| h.id == k as u64) {
            return self.instance(roster);
        }
        assert!(
            self.helper_dynamic,
            "changed helper sets require a dynamic world (ScenarioCfg::fleet_world_dynamic)"
        );
        let j_n = roster.len();
        let i_n = helpers.len();
        let e_n = i_n * j_n;
        let (mut r_ms, mut l_ms, mut lp_ms, mut rp_ms, mut p_ms, mut pp_ms) = (
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
        );
        let prof = self.cfg.model.profile();
        for (jj, c) in roster.iter().enumerate() {
            let c: &FleetClient = c.borrow();
            let dm = ClientDelayModel::new(&prof, c.cut, c.batch_ms, self.cfg.wire_factor);
            for (i, h) in helpers.iter().enumerate() {
                let e = i * j_n + jj;
                if (h.id as usize) < base_i {
                    let k = h.id as usize;
                    r_ms[e] = c.r_ms[k];
                    l_ms[e] = c.l_ms[k];
                    lp_ms[e] = c.lp_ms[k];
                    rp_ms[e] = c.rp_ms[k];
                    p_ms[e] = c.p_ms[k];
                    pp_ms[e] = c.pp_ms[k];
                } else {
                    let mut rng = Rng::seeded(self.edge_seed(c.id, h.id));
                    let rate = self.link.draw_rate(&mut rng);
                    let d = dm.draw_edge(&mut rng, &self.link, h.batch_ms, rate, self.cfg.spec.jitter_sigma);
                    r_ms[e] = d[0];
                    l_ms[e] = d[1];
                    lp_ms[e] = d[2];
                    rp_ms[e] = d[3];
                    p_ms[e] = d[4];
                    pp_ms[e] = d[5];
                }
            }
        }
        let inst = InstanceMs {
            n_clients: j_n,
            n_helpers: i_n,
            r_ms,
            l_ms,
            lp_ms,
            rp_ms,
            p_ms,
            pp_ms,
            d_gb: roster
                .iter()
                .map(|c| {
                    let c: &FleetClient = c.borrow();
                    c.d_gb
                })
                .collect(),
            mem_gb: helpers.iter().map(|h| h.mem_gb).collect(),
            mu_ms: vec![self.cfg.switch_cost_ms; i_n],
            label: format!(
                "fleet:{}/{} J={} I={} seed={}",
                self.cfg.spec.name,
                self.cfg.model.name(),
                j_n,
                i_n,
                self.cfg.seed
            ),
        };
        inst.validate().expect("fleet world produced invalid instance");
        inst
    }
}

/// Ensure a memory-feasible assignment exists: total capacity must cover
/// total demand with slack, and the largest client must fit somewhere.
/// Scales capacities up minimally when violated (documents the testbed's
/// implicit property that its helpers could host all clients).
fn repair_memory(d_gb: &[f64], mut mem: Vec<f64>) -> Vec<f64> {
    let demand: f64 = d_gb.iter().sum();
    let max_d = d_gb.iter().cloned().fold(0.0, f64::max);
    let cap: f64 = mem.iter().sum();
    if cap < 1.15 * demand {
        let scale = 1.15 * demand / cap.max(1e-9);
        for m in &mut mem {
            *m *= scale;
        }
    }
    let max_m = mem.iter().cloned().fold(0.0, f64::max);
    if max_m < max_d {
        let k = mem
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(k, _)| k)
            .unwrap();
        mem[k] = max_d * 1.05;
    }
    mem
}

/// Strong repair for the grown families: on top of [`repair_memory`]'s
/// invariants, guarantee total capacity ≥ total demand + I·max_d. At any
/// point of any sequential packing, total free ≥ I·max_d + d_j, so some
/// helper has free ≥ max_d ≥ d_j — **no** feasible-choice assignment
/// procedure (balanced greedy, random baseline, ADMM's y-subproblem) can
/// ever wedge. Uniform scaling preserves the capacity *spread* that makes
/// starved families interesting.
fn repair_memory_packable(d_gb: &[f64], mem: Vec<f64>) -> Vec<f64> {
    let mut mem = repair_memory(d_gb, mem);
    let demand: f64 = d_gb.iter().sum();
    let max_d = d_gb.iter().cloned().fold(0.0, f64::max);
    let need = demand + mem.len() as f64 * max_d;
    let cap: f64 = mem.iter().sum();
    if cap < need {
        let scale = need / cap.max(1e-9) * 1.001;
        for m in &mut mem {
            *m *= scale;
        }
    }
    mem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn deterministic() {
        let cfg = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 12, 4, 7);
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.p_ms, b.p_ms);
        assert_eq!(a.mem_gb, b.mem_gb);
    }

    #[test]
    fn seeds_differ() {
        let a = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 1).generate();
        let b = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 2).generate();
        assert_ne!(a.p_ms, b.p_ms);
    }

    #[test]
    fn scenario1_horizon_in_paper_ballpark() {
        // Paper Table II: ResNet101, J=10 → T=294 at |S_t|=180ms;
        // VGG19, J=10 → T=176 at 550ms. Accept the right order of magnitude.
        let t_avg = |model: Model, slot: f64| -> f64 {
            let mut acc = 0.0;
            for seed in 0..5u64 {
                let inst = ScenarioCfg::new(Scenario::S1, model, 10, 2, 1000 + seed).generate().quantize(slot);
                acc += inst.horizon() as f64;
            }
            acc / 5.0
        };
        let t_res = t_avg(Model::ResNet101, 180.0);
        assert!((120.0..750.0).contains(&t_res), "T(resnet)={t_res}");
        let t_vgg = t_avg(Model::Vgg19, 550.0);
        assert!((40.0..450.0).contains(&t_vgg), "T(vgg)={t_vgg}");
    }

    #[test]
    fn memory_always_repairable() {
        prop::check(60, |rng| {
            let j = rng.range_usize(1, 40);
            let i = rng.range_usize(1, 8);
            let scen = Scenario::ALL[rng.below(Scenario::ALL.len())];
            let model = if rng.chance(0.5) { Model::ResNet101 } else { Model::Vgg19 };
            let inst = ScenarioCfg::new(scen, model, j, i, rng.next_u64()).generate();
            // validate() ran inside generate(); check capacity slack too.
            let demand: f64 = inst.d_gb.iter().sum();
            let cap: f64 = inst.mem_gb.iter().sum();
            prop::assert_prop(cap >= 1.1 * demand, "capacity covers demand");
        });
    }

    #[test]
    fn scenario2_more_heterogeneous_than_scenario1() {
        // Coefficient of variation of p_ms should be larger in S2.
        let cv = |scen: Scenario| -> f64 {
            let mut cvs = vec![];
            for seed in 0..8u64 {
                let inst = ScenarioCfg::new(scen, Model::ResNet101, 20, 5, 77 + seed).generate();
                let m = inst.p_ms.iter().sum::<f64>() / inst.p_ms.len() as f64;
                let v = inst.p_ms.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / inst.p_ms.len() as f64;
                cvs.push(v.sqrt() / m);
            }
            cvs.iter().sum::<f64>() / cvs.len() as f64
        };
        assert!(cv(Scenario::S2) > cv(Scenario::S1));
    }

    #[test]
    fn scenario2_random_cuts_vary_footprints() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 3).generate();
        let min = inst.d_gb.iter().cloned().fold(f64::MAX, f64::min);
        let max = inst.d_gb.iter().cloned().fold(0.0, f64::max);
        assert!(max > min * 1.05, "footprints should differ: {min}..{max}");
    }

    #[test]
    fn switch_cost_propagates() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 4, 2, 9)
            .with_switch_cost(120.0)
            .generate();
        assert!(inst.mu_ms.iter().all(|&m| (m - 120.0).abs() < 1e-9));
    }

    // ---- composable-spec / new-family coverage --------------------------

    #[test]
    fn every_family_generates_valid_and_deterministic() {
        for scen in Scenario::ALL {
            for model in [Model::ResNet101, Model::Vgg19] {
                let cfg = ScenarioCfg::new(scen, model, 9, 3, 1234);
                let a = cfg.generate(); // validate() runs inside
                let b = cfg.generate();
                assert_eq!(a.p_ms, b.p_ms, "{} must be deterministic", scen.name());
                assert_eq!(a.mem_gb, b.mem_gb, "{} memory must be deterministic", scen.name());
                assert!(a.label.contains(scen.name()));
            }
        }
    }

    #[test]
    fn family_names_roundtrip_through_parse() {
        for scen in Scenario::ALL {
            assert_eq!(Scenario::parse(scen.name()), Some(scen), "{}", scen.name());
        }
        assert_eq!(Scenario::parse("nope"), None);
    }

    #[test]
    fn families_differ_from_presets() {
        let base = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 12, 3, 5).generate();
        for scen in [Scenario::S3Clustered, Scenario::S4StragglerTail, Scenario::S5MemoryStarved, Scenario::S6MegaHomogeneous, Scenario::S7HelperBursts, Scenario::S8FlashCrowd] {
            let inst = ScenarioCfg::new(scen, Model::ResNet101, 12, 3, 5).generate();
            assert_ne!(inst.p_ms, base.p_ms, "{} should not clone scenario1", scen.name());
        }
    }

    #[test]
    fn mega_homogeneous_is_least_heterogeneous() {
        let cv = |scen: Scenario| -> f64 {
            let mut acc = 0.0;
            for seed in 0..6u64 {
                let inst = ScenarioCfg::new(scen, Model::ResNet101, 20, 5, 900 + seed).generate();
                let m = inst.p_ms.iter().sum::<f64>() / inst.p_ms.len() as f64;
                let v = inst.p_ms.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / inst.p_ms.len() as f64;
                acc += v.sqrt() / m;
            }
            acc / 6.0
        };
        assert!(cv(Scenario::S6MegaHomogeneous) < cv(Scenario::S1), "s6 must be flatter than s1");
        assert!(cv(Scenario::S6MegaHomogeneous) < cv(Scenario::S2), "s6 must be flatter than s2");
    }

    #[test]
    fn memory_starved_varies_capacities_where_s1_does_not() {
        let mem_cv = |scen: Scenario| -> f64 {
            let mut acc = 0.0;
            for seed in 0..5u64 {
                let inst = ScenarioCfg::new(scen, Model::ResNet101, 12, 6, 40 + seed).generate();
                let m = inst.mem_gb.iter().sum::<f64>() / inst.mem_gb.len() as f64;
                let v = inst.mem_gb.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / inst.mem_gb.len() as f64;
                acc += v.sqrt() / m;
            }
            acc / 5.0
        };
        // S1 helpers all carry identical (full-RAM) capacity; repair scales
        // uniformly, so the spread stays zero. S5 draws tight varied
        // fractions.
        assert!(mem_cv(Scenario::S1) < 1e-9);
        assert!(mem_cv(Scenario::S5MemoryStarved) > 0.03);
    }

    #[test]
    fn straggler_tail_mix_has_heavy_tail() {
        let mix = DeviceMix::StragglerTail { tail_frac: 0.12, slow_factor: 8.0 };
        let mut rng = Rng::seeded(17);
        let pool = Device::client_pool();
        let mut xs: Vec<f64> = (0..400).map(|_| mix.draw_batch_ms(&mut rng, pool, Model::ResNet101)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        let max = *xs.last().unwrap();
        assert!(max / median > 4.0, "tail not heavy: median {median}, max {max}");
        // Draws stay within the straggler-inflated pool envelope.
        let pool_max = pool.iter().map(|d| d.batch_ms(Model::ResNet101)).fold(0.0f64, f64::max);
        assert!(max <= pool_max * 8.0 + 1e-6);
    }

    #[test]
    fn tier_mix_draws_stay_in_pool_envelope() {
        let mix = DeviceMix::Tiers { weights: vec![0.5, 0.35, 0.15], centers: vec![0.85, 0.5, 0.1], sigma_log: 0.06 };
        let mut rng = Rng::seeded(23);
        let pool = Device::client_pool();
        let lo = pool.iter().map(|d| d.batch_ms(Model::Vgg19)).fold(f64::MAX, f64::min);
        let hi = pool.iter().map(|d| d.batch_ms(Model::Vgg19)).fold(0.0f64, f64::max);
        for _ in 0..500 {
            let x = mix.draw_batch_ms(&mut rng, pool, Model::Vgg19);
            // centers are inside [0,1]; sigma 0.06 keeps draws within ~30%
            // of the envelope.
            assert!(x > lo * 0.5 && x < hi * 2.0, "tier draw {x} far outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn grown_families_guarantee_wedge_free_packing() {
        // The strong repair: cap ≥ demand + I·max_d, so no sequential
        // feasible-choice assignment can wedge on these families.
        for scen in [Scenario::S3Clustered, Scenario::S4StragglerTail, Scenario::S5MemoryStarved, Scenario::S6MegaHomogeneous] {
            for seed in 0..6u64 {
                let inst = ScenarioCfg::new(scen, Model::Vgg19, 11, 4, 600 + seed).generate();
                let demand: f64 = inst.d_gb.iter().sum();
                let max_d = inst.d_gb.iter().cloned().fold(0.0, f64::max);
                let cap: f64 = inst.mem_gb.iter().sum();
                assert!(
                    cap + 1e-9 >= demand + inst.n_helpers as f64 * max_d,
                    "{} seed {seed}: cap {cap} < demand {demand} + I*max_d",
                    scen.name()
                );
            }
        }
    }

    #[test]
    fn custom_spec_composition_generates() {
        let spec = ScenarioSpec::s1()
            .named("custom-wide-links")
            .with_link(LinkRegime::WideSpread)
            .with_jitter(0.2)
            .with_churn(0.1);
        let cfg = ScenarioCfg::from_spec(spec, Model::Vgg19, 8, 2, 3);
        let inst = cfg.generate();
        assert!(inst.label.contains("custom-wide-links"));
        // Different name → different RNG stream than the s1 preset.
        let s1 = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 8, 2, 3).generate();
        assert_ne!(inst.p_ms, s1.p_ms);
    }

    #[test]
    fn churn_rounds_deterministic_and_never_empty() {
        let cfg = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 10, 2, 8);
        let a = cfg.generate_rounds(6);
        let b = cfg.generate_rounds(6);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.n_clients, y.n_clients);
            assert_eq!(x.p_ms, y.p_ms, "churn rounds must be deterministic");
            assert!(x.n_clients >= 1 && x.n_clients <= 10);
        }
        // With churn on, at least one round should differ from the base.
        assert!(a.iter().any(|r| r.n_clients < 10), "churn 0.15 over 6 rounds should drop someone");
    }

    // ---- fleet world -----------------------------------------------------

    #[test]
    fn fleet_mint_deterministic_and_order_free() {
        let cfg = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 8, 3, 11);
        let w = cfg.fleet_world(16);
        let a = w.mint_client(13);
        let b = w.mint_client(13);
        assert_eq!(a.p_ms, b.p_ms);
        assert_eq!(a.cut, b.cut);
        assert_eq!(a.rates_mbps, b.rates_mbps);
        // Minting other clients in between changes nothing.
        let _ = w.mint_client(5);
        let c = w.mint_client(13);
        assert_eq!(a.p_ms, c.p_ms);
        // Distinct ids get distinct streams.
        assert_ne!(a.p_ms, w.mint_client(14).p_ms);
    }

    #[test]
    fn fleet_instance_valid_for_any_roster() {
        let cfg = ScenarioCfg::new(Scenario::S5MemoryStarved, Model::ResNet101, 6, 3, 4);
        let w = cfg.fleet_world(12);
        // validate() runs inside instance(); exercise base, mixed and
        // arrival-heavy rosters plus the empty one.
        for ids in [vec![0, 1, 2, 3, 4, 5], vec![2, 4, 9, 10], vec![11], vec![]] {
            let roster: Vec<FleetClient> = ids.iter().map(|&id| w.mint_client(id)).collect();
            let inst = w.instance(&roster);
            assert_eq!(inst.n_clients, ids.len());
            assert_eq!(inst.mem_gb, w.mem_gb, "helper capacities are fixed across rosters");
        }
    }

    #[test]
    fn fleet_instance_columns_match_mint() {
        let cfg = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 2, 9);
        let w = cfg.fleet_world(8);
        let roster: Vec<FleetClient> = [0u64, 2, 5].iter().map(|&id| w.mint_client(id)).collect();
        let inst = w.instance(&roster);
        for i in 0..2 {
            for (jj, c) in roster.iter().enumerate() {
                assert_eq!(inst.p_ms[i * 3 + jj], c.p_ms[i]);
                assert_eq!(inst.r_ms[i * 3 + jj], c.r_ms[i]);
            }
        }
        assert_eq!(inst.d_gb, vec![roster[0].d_gb, roster[1].d_gb, roster[2].d_gb]);
    }

    #[test]
    fn fleet_arrivals_draw_from_pool_distribution() {
        // S1's client mix is a uniform pool draw: every minted batch time
        // must be an exact member of the concrete pool.
        let cfg = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 6, 2, 21);
        let w = cfg.fleet_world(64);
        let pool: Vec<f64> = Device::client_pool().iter().map(|d| d.batch_ms(Model::ResNet101)).collect();
        for id in 0..60u64 {
            let c = w.mint_client(id);
            assert!(
                pool.iter().any(|&p| (p - c.batch_ms).abs() < 1e-9),
                "client {id} batch {} not in pool {pool:?}",
                c.batch_ms
            );
        }
    }

    #[test]
    fn fleet_arrivals_draw_from_link_regime() {
        // s6's links are UniformFixed: every minted rate is exactly mbps.
        let cfg = ScenarioCfg::new(Scenario::S6MegaHomogeneous, Model::Vgg19, 4, 2, 2);
        let w = cfg.fleet_world(20);
        for id in 0..16u64 {
            for &r in &w.mint_client(id).rates_mbps {
                assert!((r - 12.0).abs() < 1e-9, "uniform regime rate {r}");
            }
        }
        // And a clamped lognormal regime stays within its clamp range.
        let cfg2 = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 4, 2, 2);
        let w2 = cfg2.fleet_world(20);
        for id in 0..16u64 {
            for &r in &w2.mint_client(id).rates_mbps {
                assert!((1.0..=100.0).contains(&r), "rate {r} outside WideSpread clamp");
            }
        }
    }

    #[test]
    fn fleet_world_wedge_free_up_to_cap() {
        for scen in [Scenario::S2, Scenario::S5MemoryStarved] {
            let cfg = ScenarioCfg::new(scen, Model::ResNet101, 8, 3, 6);
            let max_clients = 16;
            let w = cfg.fleet_world(max_clients);
            let cap: f64 = w.mem_gb.iter().sum();
            assert!(
                cap + 1e-9 >= (max_clients + 3) as f64 * w.d_cap,
                "{}: cap {cap} < (max_clients + I) * d_cap {}",
                scen.name(),
                w.d_cap
            );
            // Every admissible client fits under the cap.
            for id in 0..max_clients as u64 {
                assert!(w.mint_client(id).d_gb <= w.d_cap + 1e-12);
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least one helper")]
    fn fleet_world_rejects_helper_less_configs() {
        // I = 0 breaks the wedge-free guarantee the repair relies on, so
        // construction must fail loudly instead of every later round
        // reporting full-infeasible.
        let cfg = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 0, 6);
        cfg.fleet_world(8);
    }

    // ---- dynamic worlds (runtime helper roster) --------------------------

    #[test]
    fn dynamic_world_leaves_client_minting_and_speeds_unchanged() {
        let cfg = ScenarioCfg::new(Scenario::S7HelperBursts, Model::Vgg19, 6, 3, 11);
        let (w, d) = (cfg.fleet_world(12), cfg.fleet_world_dynamic(12));
        assert!(!w.helper_modeled() && d.helper_modeled());
        assert_eq!(w.d_cap, d.d_cap);
        for id in 0..12u64 {
            assert_eq!(w.mint_client(id).p_ms, d.mint_client(id).p_ms);
        }
        for id in 0..3u64 {
            assert_eq!(w.mint_helper(id).batch_ms, d.mint_helper(id).batch_ms);
        }
    }

    #[test]
    fn dynamic_world_is_outage_proof() {
        // Every helper alone must host the whole admitted roster: mem ≥
        // (max_clients + 1)·d_cap, so no sequence of outages can wedge
        // the repair.
        for scen in [Scenario::S5MemoryStarved, Scenario::S7HelperBursts] {
            let cfg = ScenarioCfg::new(scen, Model::ResNet101, 8, 3, 6);
            let w = cfg.fleet_world_dynamic(16);
            for (k, &m) in w.mem_gb.iter().enumerate() {
                assert!(m >= 17.0 * w.d_cap, "{}: helper {k} mem {m} below floor", scen.name());
            }
            // Joined helpers mint at (or above) the same floor.
            let h = w.mint_helper(40);
            assert!(h.mem_gb >= w.helper_mem_floor);
        }
    }

    #[test]
    fn mint_helper_deterministic_and_base_ids_match_world() {
        let cfg = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 6, 3, 11);
        let w = cfg.fleet_world_dynamic(12);
        for id in 0..3u64 {
            let h = w.mint_helper(id);
            assert_eq!(h.mem_gb, w.mem_gb[id as usize]);
        }
        let a = w.mint_helper(7);
        let b = w.mint_helper(7);
        assert_eq!(a.batch_ms, b.batch_ms);
        assert_eq!(a.mem_gb, b.mem_gb);
        assert_ne!(a.batch_ms, w.mint_helper(8).batch_ms, "distinct ids, distinct streams");
    }

    #[test]
    fn instance_on_base_set_is_byte_identical_to_instance() {
        let cfg = ScenarioCfg::new(Scenario::S4StragglerTail, Model::ResNet101, 5, 3, 9);
        let w = cfg.fleet_world_dynamic(10);
        let roster: Vec<FleetClient> = (0..5u64).map(|id| w.mint_client(id)).collect();
        let helpers: Vec<FleetHelper> = (0..3u64).map(|id| w.mint_helper(id)).collect();
        let a = w.instance(&roster);
        let b = w.instance_on(&roster, &helpers);
        assert_eq!(a.p_ms, b.p_ms);
        assert_eq!(a.mem_gb, b.mem_gb);
        assert_eq!(a.mu_ms, b.mu_ms);
    }

    #[test]
    fn instance_on_survivor_subset_keeps_cached_columns() {
        let cfg = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 3, 9);
        let w = cfg.fleet_world_dynamic(8);
        let roster: Vec<FleetClient> = (0..4u64).map(|id| w.mint_client(id)).collect();
        // Helper 1 is down: columns must be the clients' cached columns
        // for helpers 0 and 2, in that order.
        let helpers = vec![w.mint_helper(0), w.mint_helper(2)];
        let inst = w.instance_on(&roster, &helpers);
        assert_eq!(inst.n_helpers, 2);
        for (jj, c) in roster.iter().enumerate() {
            assert_eq!(inst.p_ms[jj], c.p_ms[0]);
            assert_eq!(inst.p_ms[4 + jj], c.p_ms[2]);
            assert_eq!(inst.r_ms[4 + jj], c.r_ms[2]);
        }
        assert_eq!(inst.mem_gb, vec![w.mem_gb[0], w.mem_gb[2]]);
    }

    #[test]
    fn instance_on_joined_helper_columns_are_pure_and_deterministic() {
        let cfg = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 4, 2, 5);
        let w = cfg.fleet_world_dynamic(8);
        let roster: Vec<FleetClient> = (0..4u64).map(|id| w.mint_client(id)).collect();
        let helpers = vec![w.mint_helper(0), w.mint_helper(1), w.mint_helper(4)];
        let a = w.instance_on(&roster, &helpers); // validate() runs inside
        let b = w.instance_on(&roster, &helpers);
        assert_eq!(a.p_ms, b.p_ms);
        // The joined helper's columns do not depend on which other
        // helpers are present.
        let c = w.instance_on(&roster, &[w.mint_helper(4)]);
        for jj in 0..4 {
            assert_eq!(a.p_ms[2 * 4 + jj], c.p_ms[jj]);
            assert_eq!(a.l_ms[2 * 4 + jj], c.l_ms[jj]);
        }
        // And differ per joined helper id.
        let d = w.instance_on(&roster, &[w.mint_helper(5)]);
        assert_ne!(c.p_ms, d.p_ms);
    }

    #[test]
    fn zero_churn_rounds_are_identical() {
        let cfg = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 6, 2, 4);
        let rounds = cfg.generate_rounds(3);
        assert_eq!(rounds.len(), 3);
        for r in &rounds {
            assert_eq!(r.n_clients, 6);
            assert_eq!(r.p_ms, rounds[0].p_ms);
        }
    }
}
