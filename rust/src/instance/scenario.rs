//! Composable scenario generation for the parallel-SL system.
//!
//! The paper's two evaluation settings (§VII "Setup") are kept as named
//! presets of a composable [`ScenarioSpec`]:
//!
//! * **Scenario 1 (low heterogeneity)** — clients and helpers are drawn
//!   uniformly from the testbed's device types (Table I); memory = RAM;
//!   all clients share the same cut layers (ResNet101 → (3, 33), VGG19 →
//!   (3, 23)); links follow the Akamai-France model.
//! * **Scenario 2 (high heterogeneity)** — device speeds are *interpolated*
//!   between the profiled devices (log-space), memory varies per entity
//!   (upper-bounded by RAM, with a few very-low-memory helpers), clients
//!   use *randomly selected* cut layers, and links have a wider spread.
//!
//! A spec composes orthogonal axes — device-mix distribution
//! ([`DeviceMix`]), per-entity memory model ([`MemoryModel`]), link regime
//! ([`LinkRegime`]), cut-layer policy ([`CutPolicy`]), delay jitter and a
//! client-churn knob — so new workloads are one constructor away. Four
//! additional named families ship out of the box:
//!
//! * **s3-clustered** — clustered device tiers (a fleet of a few hardware
//!   generations) over cellular-like links;
//! * **s4-straggler-tail** — a mostly-uniform fleet with a heavy straggler
//!   tail and nonzero client churn (the MP-SL / wireless-SL regime);
//! * **s5-memory-starved** — random cuts + helpers with tight, varied
//!   memory: assignment feasibility is the binding constraint;
//! * **s6-mega-homogeneous** — a huge identical fleet over uniform links:
//!   the balanced-greedy end of the §VII strategy rule.
//!
//! Each generated instance is deterministic in `(scenario, model, J, I,
//! seed)` — every experiment records this tuple. The S1/S2 presets draw
//! from the RNG in exactly the seed generator's order, so historical
//! tuples reproduce byte-identical instances.

use super::network::LinkModel;
use super::profiles::{Device, Model, ModelProfile};
use super::InstanceMs;
use crate::util::rng::{fnv64 as fnv, Rng};

/// Named scenario family (the paper's §VII settings plus the grown ones).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    S1,
    S2,
    S3Clustered,
    S4StragglerTail,
    S5MemoryStarved,
    S6MegaHomogeneous,
}

impl Scenario {
    /// Every named family, in canonical order (sweep grids iterate this).
    pub const ALL: [Scenario; 6] = [
        Scenario::S1,
        Scenario::S2,
        Scenario::S3Clustered,
        Scenario::S4StragglerTail,
        Scenario::S5MemoryStarved,
        Scenario::S6MegaHomogeneous,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Scenario::S1 => "scenario1",
            Scenario::S2 => "scenario2",
            Scenario::S3Clustered => "s3-clustered",
            Scenario::S4StragglerTail => "s4-straggler-tail",
            Scenario::S5MemoryStarved => "s5-memory-starved",
            Scenario::S6MegaHomogeneous => "s6-mega-homogeneous",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "1" | "s1" | "scenario1" => Some(Scenario::S1),
            "2" | "s2" | "scenario2" => Some(Scenario::S2),
            "3" | "s3" | "s3-clustered" | "clustered" => Some(Scenario::S3Clustered),
            "4" | "s4" | "s4-straggler-tail" | "straggler-tail" | "stragglers" => Some(Scenario::S4StragglerTail),
            "5" | "s5" | "s5-memory-starved" | "memory-starved" => Some(Scenario::S5MemoryStarved),
            "6" | "s6" | "s6-mega-homogeneous" | "mega-homogeneous" => Some(Scenario::S6MegaHomogeneous),
            _ => None,
        }
    }

    /// The composable spec behind this named family.
    pub fn spec(self) -> ScenarioSpec {
        match self {
            Scenario::S1 => ScenarioSpec::s1(),
            Scenario::S2 => ScenarioSpec::s2(),
            Scenario::S3Clustered => ScenarioSpec::s3_clustered(),
            Scenario::S4StragglerTail => ScenarioSpec::s4_straggler_tail(),
            Scenario::S5MemoryStarved => ScenarioSpec::s5_memory_starved(),
            Scenario::S6MegaHomogeneous => ScenarioSpec::s6_mega_homogeneous(),
        }
    }
}

/// How entity speeds (whole-model batch times) are drawn from a device
/// pool. Each variant documents its RNG draw count per entity — presets
/// must keep the seed generator's draw order.
#[derive(Clone, Debug, PartialEq)]
pub enum DeviceMix {
    /// Uniform draw from the concrete pool (Scenario 1). One draw/entity.
    Pool,
    /// Log-space interpolation across the pool's speed continuum, widened
    /// by `widen` on both ends (Scenario 2). One draw/entity.
    LogInterp { widen: f64 },
    /// Clustered hardware tiers along the pool's log-speed continuum:
    /// a tier is picked by `weights`, centered at `centers[t]` (fraction
    /// of the log range, 0 = fastest end), with lognormal spread
    /// `sigma_log` inside the tier. `weights.len() == centers.len()`.
    Tiers { weights: Vec<f64>, centers: Vec<f64>, sigma_log: f64 },
    /// Uniform pool draw, but with probability `tail_frac` the entity is a
    /// straggler running `slow_factor`× slower (heavy right tail).
    StragglerTail { tail_frac: f64, slow_factor: f64 },
    /// Every entity is the same pool device (index into the pool); no
    /// draws — the fully homogeneous limit.
    Fixed { index: usize },
}

/// (ln(min/widen), ln(max·widen)) over the pool's batch times.
fn log_bounds(pool: &[Device], model: Model, widen: f64) -> (f64, f64) {
    let times: Vec<f64> = pool.iter().map(|d| d.batch_ms(model)).collect();
    let lo = (times.iter().cloned().fold(f64::MAX, f64::min) / widen).ln();
    let hi = (times.iter().cloned().fold(0.0f64, f64::max) * widen).ln();
    (lo, hi)
}

impl DeviceMix {
    /// Draw one entity's whole-model batch time (ms).
    pub fn draw_batch_ms(&self, rng: &mut Rng, pool: &[Device], model: Model) -> f64 {
        match self {
            DeviceMix::Pool => rng.choice(pool).batch_ms(model),
            DeviceMix::LogInterp { widen } => {
                let (lo, hi) = log_bounds(pool, model, *widen);
                rng.range_f64(lo, hi).exp()
            }
            DeviceMix::Tiers { weights, centers, sigma_log } => {
                debug_assert_eq!(weights.len(), centers.len(), "tier tables must align");
                let (lo, hi) = log_bounds(pool, model, 1.0);
                let t = rng.weighted_choice(weights);
                let center = lo + centers[t].clamp(0.0, 1.0) * (hi - lo);
                (center + rng.normal(0.0, *sigma_log)).exp()
            }
            DeviceMix::StragglerTail { tail_frac, slow_factor } => {
                let base = rng.choice(pool).batch_ms(model);
                if rng.chance(*tail_frac) {
                    base * slow_factor
                } else {
                    base
                }
            }
            DeviceMix::Fixed { index } => pool[index % pool.len()].batch_ms(model),
        }
    }
}

/// Per-client cut-layer policy (σ1, σ2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CutPolicy {
    /// The model's default cuts for every client (Scenario 1); no draws.
    Default,
    /// Per-client random cuts, σ1 early / σ2 late (Scenario 2); two
    /// draws/client: σ1 early enough that part-1 stays cheap, σ2 near the
    /// end but leaving a real part-3.
    RandomWide,
    /// The same explicit cuts for every client; no draws.
    Fixed(usize, usize),
}

impl CutPolicy {
    fn draw(&self, rng: &mut Rng, prof: &ModelProfile) -> (usize, usize) {
        match *self {
            CutPolicy::Default => prof.default_cuts,
            CutPolicy::RandomWide => {
                let n_layers = prof.n_layers();
                let s1 = rng.range_usize(2, 5.min(n_layers / 3));
                let hi = n_layers - 2;
                let lo = (n_layers * 2 / 3).max(s1 + 2).min(hi);
                let s2 = rng.range_usize(lo, hi);
                (s1, s2)
            }
            CutPolicy::Fixed(a, b) => (a, b),
        }
    }
}

/// Per-helper memory-capacity model (as a function of the backing
/// device's RAM).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemoryModel {
    /// Capacity = the device's full RAM (Scenario 1); no draws.
    FullRam,
    /// Uniform in [lo·RAM, hi·RAM] (Scenario 2 uses lo=0.15, hi=1.0:
    /// "can vary from device to device, upper-bounded by RAM"); one
    /// draw/helper.
    UniformFraction { lo: f64, hi: f64 },
}

impl MemoryModel {
    fn draw(&self, rng: &mut Rng, ram_gb: f64) -> f64 {
        match *self {
            MemoryModel::FullRam => ram_gb,
            MemoryModel::UniformFraction { lo, hi } => rng.range_f64(lo * ram_gb, hi * ram_gb),
        }
    }
}

/// Link-rate regime for the client↔helper bipartite network.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkRegime {
    /// Akamai State-of-the-Internet France Q4'16 (Scenario 1).
    AkamaiFrance,
    /// Wider spread with a slower tail (Scenario 2).
    WideSpread,
    /// Cellular-like: lower median, longer RTT overhead.
    CellularLike,
    /// Every link at exactly `mbps` (homogeneous limit).
    UniformFixed { mbps: f64 },
}

impl LinkRegime {
    pub fn model(self) -> LinkModel {
        match self {
            LinkRegime::AkamaiFrance => LinkModel::france_q4_2016(),
            LinkRegime::WideSpread => LinkModel::heterogeneous(),
            LinkRegime::CellularLike => LinkModel::cellular(),
            LinkRegime::UniformFixed { mbps } => LinkModel::uniform(mbps),
        }
    }
}

/// A composable scenario: who the devices are, how much memory helpers
/// have, what the links look like, where the cuts go, how noisy the
/// delays are, and how flaky the clients are.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Family name; mixed into the RNG seed and recorded in every
    /// instance label (presets keep the seed generator's names so
    /// historical tuples reproduce).
    pub name: String,
    pub client_mix: DeviceMix,
    pub helper_mix: DeviceMix,
    pub cut_policy: CutPolicy,
    pub memory: MemoryModel,
    pub link: LinkRegime,
    /// Multiplicative jitter (lognormal σ) applied to every profiled time.
    pub jitter_sigma: f64,
    /// Per-round probability that a client drops out (consumed by
    /// [`ScenarioCfg::generate_rounds`]; `generate` ignores it).
    pub churn: f64,
    /// When true, memory repair additionally guarantees *wedge-free
    /// sequential packing*: total capacity ≥ total demand + I·max_d, which
    /// makes **any** sequential feasible-choice assignment (balanced
    /// greedy, the random baseline, ADMM's y-subproblem) succeed
    /// unconditionally. The legacy presets keep the seed generator's
    /// weaker aggregate-slack repair so historical `(scenario, model, J,
    /// I, seed)` tuples stay byte-identical.
    pub packable: bool,
}

impl ScenarioSpec {
    /// Paper Scenario 1 (low heterogeneity).
    pub fn s1() -> ScenarioSpec {
        ScenarioSpec {
            name: "scenario1".to_string(),
            client_mix: DeviceMix::Pool,
            helper_mix: DeviceMix::Pool,
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::AkamaiFrance,
            jitter_sigma: 0.08,
            churn: 0.0,
            packable: false,
        }
    }

    /// Paper Scenario 2 (high heterogeneity). The helper pool (VM, M1)
    /// spans a narrow 2–3.6 s band, so helper speeds widen the continuum
    /// by 2× on both ends — S2 must be *more* heterogeneous than S1's two
    /// fixed helper types (§VII explicitly has "a few helpers with very
    /// limited" capabilities).
    pub fn s2() -> ScenarioSpec {
        ScenarioSpec {
            name: "scenario2".to_string(),
            client_mix: DeviceMix::LogInterp { widen: 1.0 },
            helper_mix: DeviceMix::LogInterp { widen: 2.0 },
            cut_policy: CutPolicy::RandomWide,
            memory: MemoryModel::UniformFraction { lo: 0.15, hi: 1.0 },
            link: LinkRegime::WideSpread,
            jitter_sigma: 0.15,
            churn: 0.0,
            packable: false,
        }
    }

    /// Clustered hardware generations over cellular-like links: half the
    /// fleet is slow, a third mid-range, a sixth fast.
    pub fn s3_clustered() -> ScenarioSpec {
        ScenarioSpec {
            name: "s3-clustered".to_string(),
            client_mix: DeviceMix::Tiers {
                weights: vec![0.5, 0.35, 0.15],
                centers: vec![0.85, 0.5, 0.1],
                sigma_log: 0.06,
            },
            helper_mix: DeviceMix::Tiers {
                weights: vec![0.6, 0.4],
                centers: vec![0.3, 0.8],
                sigma_log: 0.05,
            },
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::CellularLike,
            jitter_sigma: 0.10,
            churn: 0.0,
            packable: true,
        }
    }

    /// Mostly-uniform fleet with a heavy straggler tail and client churn.
    pub fn s4_straggler_tail() -> ScenarioSpec {
        ScenarioSpec {
            name: "s4-straggler-tail".to_string(),
            client_mix: DeviceMix::StragglerTail { tail_frac: 0.12, slow_factor: 8.0 },
            helper_mix: DeviceMix::StragglerTail { tail_frac: 0.08, slow_factor: 4.0 },
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::AkamaiFrance,
            jitter_sigma: 0.10,
            churn: 0.15,
            packable: true,
        }
    }

    /// Tight, varied helper memory with per-client random cuts: the
    /// assignment-feasibility stress family.
    pub fn s5_memory_starved() -> ScenarioSpec {
        ScenarioSpec {
            name: "s5-memory-starved".to_string(),
            client_mix: DeviceMix::Pool,
            helper_mix: DeviceMix::Pool,
            cut_policy: CutPolicy::RandomWide,
            memory: MemoryModel::UniformFraction { lo: 0.06, hi: 0.30 },
            link: LinkRegime::AkamaiFrance,
            jitter_sigma: 0.08,
            churn: 0.0,
            packable: true,
        }
    }

    /// A huge identical fleet over uniform links: the balanced-greedy end
    /// of the §VII strategy rule.
    pub fn s6_mega_homogeneous() -> ScenarioSpec {
        ScenarioSpec {
            name: "s6-mega-homogeneous".to_string(),
            client_mix: DeviceMix::Fixed { index: 0 },
            helper_mix: DeviceMix::Fixed { index: 0 },
            cut_policy: CutPolicy::Default,
            memory: MemoryModel::FullRam,
            link: LinkRegime::UniformFixed { mbps: 12.0 },
            jitter_sigma: 0.02,
            churn: 0.0,
            packable: true,
        }
    }

    // ---- builder-style composition --------------------------------------

    pub fn named(mut self, name: &str) -> ScenarioSpec {
        self.name = name.to_string();
        self
    }
    pub fn with_link(mut self, link: LinkRegime) -> ScenarioSpec {
        self.link = link;
        self
    }
    pub fn with_memory(mut self, memory: MemoryModel) -> ScenarioSpec {
        self.memory = memory;
        self
    }
    pub fn with_cuts(mut self, cut_policy: CutPolicy) -> ScenarioSpec {
        self.cut_policy = cut_policy;
        self
    }
    pub fn with_client_mix(mut self, mix: DeviceMix) -> ScenarioSpec {
        self.client_mix = mix;
        self
    }
    pub fn with_helper_mix(mut self, mix: DeviceMix) -> ScenarioSpec {
        self.helper_mix = mix;
        self
    }
    pub fn with_jitter(mut self, sigma: f64) -> ScenarioSpec {
        self.jitter_sigma = sigma;
        self
    }
    pub fn with_churn(mut self, p: f64) -> ScenarioSpec {
        self.churn = p;
        self
    }
    pub fn with_packable(mut self, packable: bool) -> ScenarioSpec {
        self.packable = packable;
        self
    }
}

/// Generator configuration: a spec plus the experiment tuple.
#[derive(Clone, Debug)]
pub struct ScenarioCfg {
    pub spec: ScenarioSpec,
    pub model: Model,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    /// Activation wire-size factor: fraction of the raw fp32 activation
    /// tensor actually shipped (fp16 + activation compression on the
    /// testbed). Calibrated so horizons land near the paper's reported
    /// range (T≈294 for ResNet101 J=10 at |S_t|=180ms; T≈176 for VGG19
    /// at 550ms) — see DESIGN.md substitution table.
    pub wire_factor: f64,
    /// Per-helper preemption switching cost, ms (0 = paper's base model).
    pub switch_cost_ms: f64,
}

impl ScenarioCfg {
    pub fn new(scenario: Scenario, model: Model, n_clients: usize, n_helpers: usize, seed: u64) -> Self {
        Self::from_spec(scenario.spec(), model, n_clients, n_helpers, seed)
    }

    /// Build from a custom composed spec.
    pub fn from_spec(spec: ScenarioSpec, model: Model, n_clients: usize, n_helpers: usize, seed: u64) -> Self {
        ScenarioCfg {
            spec,
            model,
            n_clients,
            n_helpers,
            seed,
            wire_factor: 0.10,
            switch_cost_ms: 0.0,
        }
    }

    pub fn with_switch_cost(mut self, ms: f64) -> Self {
        self.switch_cost_ms = ms;
        self
    }

    /// Generate the instance.
    pub fn generate(&self) -> InstanceMs {
        let mut rng = Rng::seeded(self.seed ^ fnv(&self.spec.name) ^ fnv(self.model.name()));
        let prof = self.model.profile();
        let n_layers = prof.n_layers();
        let (j_n, i_n) = (self.n_clients, self.n_helpers);

        // --- per-client cut layers -------------------------------------
        let cuts: Vec<(usize, usize)> = (0..j_n).map(|_| self.spec.cut_policy.draw(&mut rng, &prof)).collect();

        // --- device speed factors ---------------------------------------
        // For each entity we derive a whole-model batch time (ms) from the
        // spec's device mix over the role's pool.
        let client_pool = Device::client_pool();
        let helper_pool = Device::helper_pool();
        let model = self.model;
        let client_batch_ms: Vec<f64> = (0..j_n)
            .map(|_| self.spec.client_mix.draw_batch_ms(&mut rng, client_pool, model))
            .collect();
        let helper_batch_ms: Vec<f64> = (0..i_n)
            .map(|_| self.spec.helper_mix.draw_batch_ms(&mut rng, helper_pool, model))
            .collect();

        // --- memory -------------------------------------------------------
        let d_gb: Vec<f64> = cuts.iter().map(|&c| prof.part2_footprint_gb(c)).collect();
        let helper_ram: Vec<f64> = (0..i_n)
            .map(|k| {
                let ram = helper_pool[k % helper_pool.len()].profile().ram_gb;
                self.spec.memory.draw(&mut rng, ram)
            })
            .collect();
        let mem_gb = if self.spec.packable {
            repair_memory_packable(&d_gb, helper_ram)
        } else {
            repair_memory(&d_gb, helper_ram)
        };

        // --- links ---------------------------------------------------------
        let link = self.spec.link.model();
        let rates = link.draw_rates(&mut rng, i_n, j_n);

        // --- per-edge delay vectors ----------------------------------------
        let total_w = prof.total_weight();
        let e_n = i_n * j_n;
        let (mut r_ms, mut l_ms, mut lp_ms, mut rp_ms, mut p_ms, mut pp_ms) = (
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
        );
        let jit = |rng: &mut Rng, x: f64, sigma: f64| rng.lognormal_median(x, sigma);
        for j in 0..j_n {
            let (s1, s2) = cuts[j];
            // Client-side compute (whole-batch time scaled by part share,
            // then split fwd/bwd by the model's fwd fraction).
            let share = |a: usize, b: usize| if a > b { 0.0 } else { prof.weight_range(a, b) / total_w };
            let f = prof.fwd_frac;
            let part1 = client_batch_ms[j] * share(1, s1);
            let part3 = client_batch_ms[j] * share(s2 + 1, n_layers);
            let (p1_f, p1_b) = (part1 * f, part1 * (1.0 - f));
            let (p3_f, p3_b) = (part3 * f, part3 * (1.0 - f));
            // Wire sizes (MB): activations at σ1 and σ2 (grad ≈ act size).
            let a1_mb = prof.act_mb(s1) * self.wire_factor;
            let a2_mb = prof.act_mb(s2) * self.wire_factor;
            for i in 0..i_n {
                let e = i * j_n + j;
                let rate = rates[e];
                let up1 = link.transfer_ms(a1_mb, rate);
                let dn2 = link.transfer_ms(a2_mb, rate);
                let up2 = link.transfer_ms(a2_mb, rate);
                let dn1 = link.transfer_ms(a1_mb, rate);
                let part2 = helper_batch_ms[i] * share(s1 + 1, s2);
                let s = self.spec.jitter_sigma;
                r_ms[e] = jit(&mut rng, p1_f + up1, s);
                l_ms[e] = jit(&mut rng, dn2 + p3_f, s);
                lp_ms[e] = jit(&mut rng, p3_b + up2, s);
                rp_ms[e] = jit(&mut rng, dn1 + p1_b, s);
                p_ms[e] = jit(&mut rng, (part2 * f).max(1.0), s);
                pp_ms[e] = jit(&mut rng, (part2 * (1.0 - f)).max(1.0), s);
            }
        }

        let inst = InstanceMs {
            n_clients: j_n,
            n_helpers: i_n,
            r_ms,
            l_ms,
            lp_ms,
            rp_ms,
            p_ms,
            pp_ms,
            d_gb,
            mem_gb,
            mu_ms: vec![self.switch_cost_ms; i_n],
            label: format!(
                "{}/{} J={} I={} seed={}",
                self.spec.name,
                self.model.name(),
                j_n,
                i_n,
                self.seed
            ),
        };
        inst.validate().expect("generator produced invalid instance");
        inst
    }

    /// Generate a churn round sequence: the base instance projected onto
    /// the clients that stayed for each round. With `spec.churn == 0`
    /// every round is the full instance. Deterministic in the tuple —
    /// the churn stream is derived from the same seed, independent of the
    /// instance stream.
    pub fn generate_rounds(&self, rounds: usize) -> Vec<InstanceMs> {
        let base = self.generate();
        if self.spec.churn <= 0.0 || base.n_clients <= 1 {
            return vec![base; rounds];
        }
        let mut rng = Rng::seeded(self.seed ^ fnv(&self.spec.name) ^ fnv("churn"));
        (0..rounds)
            .map(|round| {
                let mut keep: Vec<usize> = (0..base.n_clients).filter(|_| !rng.chance(self.spec.churn)).collect();
                if keep.is_empty() {
                    keep.push(rng.below(base.n_clients));
                }
                let mut inst = base.restrict_clients(&keep);
                inst.label = format!("{} round={round} J'={}", base.label, keep.len());
                inst
            })
            .collect()
    }
}

/// Ensure a memory-feasible assignment exists: total capacity must cover
/// total demand with slack, and the largest client must fit somewhere.
/// Scales capacities up minimally when violated (documents the testbed's
/// implicit property that its helpers could host all clients).
fn repair_memory(d_gb: &[f64], mut mem: Vec<f64>) -> Vec<f64> {
    let demand: f64 = d_gb.iter().sum();
    let max_d = d_gb.iter().cloned().fold(0.0, f64::max);
    let cap: f64 = mem.iter().sum();
    if cap < 1.15 * demand {
        let scale = 1.15 * demand / cap.max(1e-9);
        for m in &mut mem {
            *m *= scale;
        }
    }
    let max_m = mem.iter().cloned().fold(0.0, f64::max);
    if max_m < max_d {
        let k = mem
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(k, _)| k)
            .unwrap();
        mem[k] = max_d * 1.05;
    }
    mem
}

/// Strong repair for the grown families: on top of [`repair_memory`]'s
/// invariants, guarantee total capacity ≥ total demand + I·max_d. At any
/// point of any sequential packing, total free ≥ I·max_d + d_j, so some
/// helper has free ≥ max_d ≥ d_j — **no** feasible-choice assignment
/// procedure (balanced greedy, random baseline, ADMM's y-subproblem) can
/// ever wedge. Uniform scaling preserves the capacity *spread* that makes
/// starved families interesting.
fn repair_memory_packable(d_gb: &[f64], mem: Vec<f64>) -> Vec<f64> {
    let mut mem = repair_memory(d_gb, mem);
    let demand: f64 = d_gb.iter().sum();
    let max_d = d_gb.iter().cloned().fold(0.0, f64::max);
    let need = demand + mem.len() as f64 * max_d;
    let cap: f64 = mem.iter().sum();
    if cap < need {
        let scale = need / cap.max(1e-9) * 1.001;
        for m in &mut mem {
            *m *= scale;
        }
    }
    mem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn deterministic() {
        let cfg = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 12, 4, 7);
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.p_ms, b.p_ms);
        assert_eq!(a.mem_gb, b.mem_gb);
    }

    #[test]
    fn seeds_differ() {
        let a = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 1).generate();
        let b = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 2).generate();
        assert_ne!(a.p_ms, b.p_ms);
    }

    #[test]
    fn scenario1_horizon_in_paper_ballpark() {
        // Paper Table II: ResNet101, J=10 → T=294 at |S_t|=180ms;
        // VGG19, J=10 → T=176 at 550ms. Accept the right order of magnitude.
        let t_avg = |model: Model, slot: f64| -> f64 {
            let mut acc = 0.0;
            for seed in 0..5u64 {
                let inst = ScenarioCfg::new(Scenario::S1, model, 10, 2, 1000 + seed).generate().quantize(slot);
                acc += inst.horizon() as f64;
            }
            acc / 5.0
        };
        let t_res = t_avg(Model::ResNet101, 180.0);
        assert!((120.0..750.0).contains(&t_res), "T(resnet)={t_res}");
        let t_vgg = t_avg(Model::Vgg19, 550.0);
        assert!((40.0..450.0).contains(&t_vgg), "T(vgg)={t_vgg}");
    }

    #[test]
    fn memory_always_repairable() {
        prop::check(60, |rng| {
            let j = rng.range_usize(1, 40);
            let i = rng.range_usize(1, 8);
            let scen = Scenario::ALL[rng.below(Scenario::ALL.len())];
            let model = if rng.chance(0.5) { Model::ResNet101 } else { Model::Vgg19 };
            let inst = ScenarioCfg::new(scen, model, j, i, rng.next_u64()).generate();
            // validate() ran inside generate(); check capacity slack too.
            let demand: f64 = inst.d_gb.iter().sum();
            let cap: f64 = inst.mem_gb.iter().sum();
            prop::assert_prop(cap >= 1.1 * demand, "capacity covers demand");
        });
    }

    #[test]
    fn scenario2_more_heterogeneous_than_scenario1() {
        // Coefficient of variation of p_ms should be larger in S2.
        let cv = |scen: Scenario| -> f64 {
            let mut cvs = vec![];
            for seed in 0..8u64 {
                let inst = ScenarioCfg::new(scen, Model::ResNet101, 20, 5, 77 + seed).generate();
                let m = inst.p_ms.iter().sum::<f64>() / inst.p_ms.len() as f64;
                let v = inst.p_ms.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / inst.p_ms.len() as f64;
                cvs.push(v.sqrt() / m);
            }
            cvs.iter().sum::<f64>() / cvs.len() as f64
        };
        assert!(cv(Scenario::S2) > cv(Scenario::S1));
    }

    #[test]
    fn scenario2_random_cuts_vary_footprints() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 3).generate();
        let min = inst.d_gb.iter().cloned().fold(f64::MAX, f64::min);
        let max = inst.d_gb.iter().cloned().fold(0.0, f64::max);
        assert!(max > min * 1.05, "footprints should differ: {min}..{max}");
    }

    #[test]
    fn switch_cost_propagates() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 4, 2, 9)
            .with_switch_cost(120.0)
            .generate();
        assert!(inst.mu_ms.iter().all(|&m| (m - 120.0).abs() < 1e-9));
    }

    // ---- composable-spec / new-family coverage --------------------------

    #[test]
    fn every_family_generates_valid_and_deterministic() {
        for scen in Scenario::ALL {
            for model in [Model::ResNet101, Model::Vgg19] {
                let cfg = ScenarioCfg::new(scen, model, 9, 3, 1234);
                let a = cfg.generate(); // validate() runs inside
                let b = cfg.generate();
                assert_eq!(a.p_ms, b.p_ms, "{} must be deterministic", scen.name());
                assert_eq!(a.mem_gb, b.mem_gb, "{} memory must be deterministic", scen.name());
                assert!(a.label.contains(scen.name()));
            }
        }
    }

    #[test]
    fn family_names_roundtrip_through_parse() {
        for scen in Scenario::ALL {
            assert_eq!(Scenario::parse(scen.name()), Some(scen), "{}", scen.name());
        }
        assert_eq!(Scenario::parse("nope"), None);
    }

    #[test]
    fn families_differ_from_presets() {
        let base = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 12, 3, 5).generate();
        for scen in [Scenario::S3Clustered, Scenario::S4StragglerTail, Scenario::S5MemoryStarved, Scenario::S6MegaHomogeneous] {
            let inst = ScenarioCfg::new(scen, Model::ResNet101, 12, 3, 5).generate();
            assert_ne!(inst.p_ms, base.p_ms, "{} should not clone scenario1", scen.name());
        }
    }

    #[test]
    fn mega_homogeneous_is_least_heterogeneous() {
        let cv = |scen: Scenario| -> f64 {
            let mut acc = 0.0;
            for seed in 0..6u64 {
                let inst = ScenarioCfg::new(scen, Model::ResNet101, 20, 5, 900 + seed).generate();
                let m = inst.p_ms.iter().sum::<f64>() / inst.p_ms.len() as f64;
                let v = inst.p_ms.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / inst.p_ms.len() as f64;
                acc += v.sqrt() / m;
            }
            acc / 6.0
        };
        assert!(cv(Scenario::S6MegaHomogeneous) < cv(Scenario::S1), "s6 must be flatter than s1");
        assert!(cv(Scenario::S6MegaHomogeneous) < cv(Scenario::S2), "s6 must be flatter than s2");
    }

    #[test]
    fn memory_starved_varies_capacities_where_s1_does_not() {
        let mem_cv = |scen: Scenario| -> f64 {
            let mut acc = 0.0;
            for seed in 0..5u64 {
                let inst = ScenarioCfg::new(scen, Model::ResNet101, 12, 6, 40 + seed).generate();
                let m = inst.mem_gb.iter().sum::<f64>() / inst.mem_gb.len() as f64;
                let v = inst.mem_gb.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / inst.mem_gb.len() as f64;
                acc += v.sqrt() / m;
            }
            acc / 5.0
        };
        // S1 helpers all carry identical (full-RAM) capacity; repair scales
        // uniformly, so the spread stays zero. S5 draws tight varied
        // fractions.
        assert!(mem_cv(Scenario::S1) < 1e-9);
        assert!(mem_cv(Scenario::S5MemoryStarved) > 0.03);
    }

    #[test]
    fn straggler_tail_mix_has_heavy_tail() {
        let mix = DeviceMix::StragglerTail { tail_frac: 0.12, slow_factor: 8.0 };
        let mut rng = Rng::seeded(17);
        let pool = Device::client_pool();
        let mut xs: Vec<f64> = (0..400).map(|_| mix.draw_batch_ms(&mut rng, pool, Model::ResNet101)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        let max = *xs.last().unwrap();
        assert!(max / median > 4.0, "tail not heavy: median {median}, max {max}");
        // Draws stay within the straggler-inflated pool envelope.
        let pool_max = pool.iter().map(|d| d.batch_ms(Model::ResNet101)).fold(0.0f64, f64::max);
        assert!(max <= pool_max * 8.0 + 1e-6);
    }

    #[test]
    fn tier_mix_draws_stay_in_pool_envelope() {
        let mix = DeviceMix::Tiers { weights: vec![0.5, 0.35, 0.15], centers: vec![0.85, 0.5, 0.1], sigma_log: 0.06 };
        let mut rng = Rng::seeded(23);
        let pool = Device::client_pool();
        let lo = pool.iter().map(|d| d.batch_ms(Model::Vgg19)).fold(f64::MAX, f64::min);
        let hi = pool.iter().map(|d| d.batch_ms(Model::Vgg19)).fold(0.0f64, f64::max);
        for _ in 0..500 {
            let x = mix.draw_batch_ms(&mut rng, pool, Model::Vgg19);
            // centers are inside [0,1]; sigma 0.06 keeps draws within ~30%
            // of the envelope.
            assert!(x > lo * 0.5 && x < hi * 2.0, "tier draw {x} far outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn grown_families_guarantee_wedge_free_packing() {
        // The strong repair: cap ≥ demand + I·max_d, so no sequential
        // feasible-choice assignment can wedge on these families.
        for scen in [Scenario::S3Clustered, Scenario::S4StragglerTail, Scenario::S5MemoryStarved, Scenario::S6MegaHomogeneous] {
            for seed in 0..6u64 {
                let inst = ScenarioCfg::new(scen, Model::Vgg19, 11, 4, 600 + seed).generate();
                let demand: f64 = inst.d_gb.iter().sum();
                let max_d = inst.d_gb.iter().cloned().fold(0.0, f64::max);
                let cap: f64 = inst.mem_gb.iter().sum();
                assert!(
                    cap + 1e-9 >= demand + inst.n_helpers as f64 * max_d,
                    "{} seed {seed}: cap {cap} < demand {demand} + I*max_d",
                    scen.name()
                );
            }
        }
    }

    #[test]
    fn custom_spec_composition_generates() {
        let spec = ScenarioSpec::s1()
            .named("custom-wide-links")
            .with_link(LinkRegime::WideSpread)
            .with_jitter(0.2)
            .with_churn(0.1);
        let cfg = ScenarioCfg::from_spec(spec, Model::Vgg19, 8, 2, 3);
        let inst = cfg.generate();
        assert!(inst.label.contains("custom-wide-links"));
        // Different name → different RNG stream than the s1 preset.
        let s1 = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 8, 2, 3).generate();
        assert_ne!(inst.p_ms, s1.p_ms);
    }

    #[test]
    fn churn_rounds_deterministic_and_never_empty() {
        let cfg = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 10, 2, 8);
        let a = cfg.generate_rounds(6);
        let b = cfg.generate_rounds(6);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.n_clients, y.n_clients);
            assert_eq!(x.p_ms, y.p_ms, "churn rounds must be deterministic");
            assert!(x.n_clients >= 1 && x.n_clients <= 10);
        }
        // With churn on, at least one round should differ from the base.
        assert!(a.iter().any(|r| r.n_clients < 10), "churn 0.15 over 6 rounds should drop someone");
    }

    #[test]
    fn zero_churn_rounds_are_identical() {
        let cfg = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 6, 2, 4);
        let rounds = cfg.generate_rounds(3);
        assert_eq!(rounds.len(), 3);
        for r in &rounds {
            assert_eq!(r.n_clients, 6);
            assert_eq!(r.p_ms, rounds[0].p_ms);
        }
    }
}
