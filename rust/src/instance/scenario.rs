//! Scenario generators reproducing the paper's two evaluation settings
//! (§VII "Setup"):
//!
//! * **Scenario 1 (low heterogeneity)** — clients and helpers are drawn
//!   uniformly from the testbed's device types (Table I); memory = RAM;
//!   all clients share the same cut layers (ResNet101 → (3, 33), VGG19 →
//!   (3, 23)); links follow the Akamai-France model.
//! * **Scenario 2 (high heterogeneity)** — device speeds are *interpolated*
//!   between the profiled devices (log-space), memory varies per entity
//!   (upper-bounded by RAM, with a few very-low-memory helpers), clients
//!   use *randomly selected* cut layers, and links have a wider spread.
//!
//! Each generated instance is deterministic in `(scenario, model, J, I,
//! seed)` — every experiment records this tuple.

use super::network::LinkModel;
use super::profiles::{Device, Model};
use super::InstanceMs;
use crate::util::rng::Rng;

/// Scenario identifier (paper §VII).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    S1,
    S2,
}

impl Scenario {
    pub fn name(self) -> &'static str {
        match self {
            Scenario::S1 => "scenario1",
            Scenario::S2 => "scenario2",
        }
    }
    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "1" | "s1" | "scenario1" => Some(Scenario::S1),
            "2" | "s2" | "scenario2" => Some(Scenario::S2),
            _ => None,
        }
    }
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct ScenarioCfg {
    pub scenario: Scenario,
    pub model: Model,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    /// Activation wire-size factor: fraction of the raw fp32 activation
    /// tensor actually shipped (fp16 + activation compression on the
    /// testbed). Calibrated so horizons land near the paper's reported
    /// range (T≈294 for ResNet101 J=10 at |S_t|=180ms; T≈176 for VGG19
    /// at 550ms) — see DESIGN.md substitution table.
    pub wire_factor: f64,
    /// Multiplicative jitter (lognormal σ) applied to every profiled time.
    pub jitter_sigma: f64,
    /// Per-helper preemption switching cost, ms (0 = paper's base model).
    pub switch_cost_ms: f64,
}

impl ScenarioCfg {
    pub fn new(scenario: Scenario, model: Model, n_clients: usize, n_helpers: usize, seed: u64) -> Self {
        ScenarioCfg {
            scenario,
            model,
            n_clients,
            n_helpers,
            seed,
            wire_factor: 0.10,
            jitter_sigma: match scenario {
                Scenario::S1 => 0.08,
                Scenario::S2 => 0.15,
            },
            switch_cost_ms: 0.0,
        }
    }

    pub fn with_switch_cost(mut self, ms: f64) -> Self {
        self.switch_cost_ms = ms;
        self
    }

    /// Generate the instance.
    pub fn generate(&self) -> InstanceMs {
        let mut rng = Rng::seeded(self.seed ^ fnv(self.scenario.name()) ^ fnv(self.model.name()));
        let prof = self.model.profile();
        let n_layers = prof.n_layers();
        let (j_n, i_n) = (self.n_clients, self.n_helpers);

        // --- per-client cut layers -------------------------------------
        let cuts: Vec<(usize, usize)> = (0..j_n)
            .map(|_| match self.scenario {
                Scenario::S1 => prof.default_cuts,
                Scenario::S2 => {
                    // Random cuts: σ1 early (keep part-1 cheap enough for the
                    // device), σ2 near the end but leaving a real part-3.
                    let s1 = rng.range_usize(2, 5.min(n_layers / 3));
                    let hi = n_layers - 2;
                    let lo = (n_layers * 2 / 3).max(s1 + 2).min(hi);
                    let s2 = rng.range_usize(lo, hi);
                    (s1, s2)
                }
            })
            .collect();

        // --- device speed factors ---------------------------------------
        // For each entity we derive a whole-model batch time (ms). S1 picks
        // a concrete testbed device; S2 interpolates between the pool's
        // fastest and slowest in log space (paper: "interpolating the time
        // measurements of the profiled devices").
        let client_pool = Device::client_pool();
        let helper_pool = Device::helper_pool();
        let model = self.model;
        // S2 interpolates device speeds in log space ("interpolating the
        // time measurements of the profiled devices"). The helper pool
        // (VM, M1) spans a narrow 2–3.6 s band, so for helpers we widen
        // the continuum by 2× on both ends — S2 must be *more*
        // heterogeneous than S1's two fixed helper types (§VII explicitly
        // has "a few helpers with very limited" capabilities in S2).
        let log_interp = |rng: &mut Rng, pool: &[Device], widen: f64| -> f64 {
            let times: Vec<f64> = pool.iter().map(|d| d.batch_ms(model)).collect();
            let lo = (times.iter().cloned().fold(f64::MAX, f64::min) / widen).ln();
            let hi = (times.iter().cloned().fold(0.0f64, f64::max) * widen).ln();
            (rng.range_f64(lo, hi)).exp()
        };
        let client_batch_ms: Vec<f64> = (0..j_n)
            .map(|_| match self.scenario {
                Scenario::S1 => rng.choice(client_pool).batch_ms(model),
                Scenario::S2 => log_interp(&mut rng, client_pool, 1.0),
            })
            .collect();
        let helper_batch_ms: Vec<f64> = (0..i_n)
            .map(|_| match self.scenario {
                Scenario::S1 => rng.choice(helper_pool).batch_ms(model),
                Scenario::S2 => log_interp(&mut rng, helper_pool, 2.0),
            })
            .collect();

        // --- memory -------------------------------------------------------
        let d_gb: Vec<f64> = cuts.iter().map(|&c| prof.part2_footprint_gb(c)).collect();
        let helper_ram: Vec<f64> = (0..i_n)
            .map(|k| match self.scenario {
                Scenario::S1 => helper_pool[k % helper_pool.len()].profile().ram_gb,
                Scenario::S2 => {
                    // "can vary from device to device, upper-bounded by RAM";
                    // a few helpers end up with very limited memory (§VII).
                    let ram = helper_pool[k % helper_pool.len()].profile().ram_gb;
                    rng.range_f64(0.15 * ram, ram)
                }
            })
            .collect();
        let mem_gb = repair_memory(&d_gb, helper_ram);

        // --- links ---------------------------------------------------------
        let link = match self.scenario {
            Scenario::S1 => LinkModel::france_q4_2016(),
            Scenario::S2 => LinkModel::heterogeneous(),
        };
        let rates = link.draw_rates(&mut rng, i_n, j_n);

        // --- per-edge delay vectors ----------------------------------------
        let total_w = prof.total_weight();
        let e_n = i_n * j_n;
        let (mut r_ms, mut l_ms, mut lp_ms, mut rp_ms, mut p_ms, mut pp_ms) = (
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
            vec![0.0; e_n],
        );
        let jit = |rng: &mut Rng, x: f64, sigma: f64| rng.lognormal_median(x, sigma);
        for j in 0..j_n {
            let (s1, s2) = cuts[j];
            // Client-side compute (whole-batch time scaled by part share,
            // then split fwd/bwd by the model's fwd fraction).
            let share = |a: usize, b: usize| if a > b { 0.0 } else { prof.weight_range(a, b) / total_w };
            let f = prof.fwd_frac;
            let part1 = client_batch_ms[j] * share(1, s1);
            let part3 = client_batch_ms[j] * share(s2 + 1, n_layers);
            let (p1_f, p1_b) = (part1 * f, part1 * (1.0 - f));
            let (p3_f, p3_b) = (part3 * f, part3 * (1.0 - f));
            // Wire sizes (MB): activations at σ1 and σ2 (grad ≈ act size).
            let a1_mb = prof.act_mb(s1) * self.wire_factor;
            let a2_mb = prof.act_mb(s2) * self.wire_factor;
            for i in 0..i_n {
                let e = i * j_n + j;
                let rate = rates[e];
                let up1 = link.transfer_ms(a1_mb, rate);
                let dn2 = link.transfer_ms(a2_mb, rate);
                let up2 = link.transfer_ms(a2_mb, rate);
                let dn1 = link.transfer_ms(a1_mb, rate);
                let part2 = helper_batch_ms[i] * share(s1 + 1, s2);
                let s = self.jitter_sigma;
                r_ms[e] = jit(&mut rng, p1_f + up1, s);
                l_ms[e] = jit(&mut rng, dn2 + p3_f, s);
                lp_ms[e] = jit(&mut rng, p3_b + up2, s);
                rp_ms[e] = jit(&mut rng, dn1 + p1_b, s);
                p_ms[e] = jit(&mut rng, (part2 * f).max(1.0), s);
                pp_ms[e] = jit(&mut rng, (part2 * (1.0 - f)).max(1.0), s);
            }
        }

        let inst = InstanceMs {
            n_clients: j_n,
            n_helpers: i_n,
            r_ms,
            l_ms,
            lp_ms,
            rp_ms,
            p_ms,
            pp_ms,
            d_gb,
            mem_gb,
            mu_ms: vec![self.switch_cost_ms; i_n],
            label: format!(
                "{}/{} J={} I={} seed={}",
                self.scenario.name(),
                self.model.name(),
                j_n,
                i_n,
                self.seed
            ),
        };
        inst.validate().expect("generator produced invalid instance");
        inst
    }
}

/// Ensure a memory-feasible assignment exists: total capacity must cover
/// total demand with slack, and the largest client must fit somewhere.
/// Scales capacities up minimally when violated (documents the testbed's
/// implicit property that its helpers could host all clients).
fn repair_memory(d_gb: &[f64], mut mem: Vec<f64>) -> Vec<f64> {
    let demand: f64 = d_gb.iter().sum();
    let max_d = d_gb.iter().cloned().fold(0.0, f64::max);
    let cap: f64 = mem.iter().sum();
    if cap < 1.15 * demand {
        let scale = 1.15 * demand / cap.max(1e-9);
        for m in &mut mem {
            *m *= scale;
        }
    }
    let max_m = mem.iter().cloned().fold(0.0, f64::max);
    if max_m < max_d {
        let k = mem
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(k, _)| k)
            .unwrap();
        mem[k] = max_d * 1.05;
    }
    mem
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn deterministic() {
        let cfg = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 12, 4, 7);
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.p_ms, b.p_ms);
        assert_eq!(a.mem_gb, b.mem_gb);
    }

    #[test]
    fn seeds_differ() {
        let a = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 1).generate();
        let b = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 2).generate();
        assert_ne!(a.p_ms, b.p_ms);
    }

    #[test]
    fn scenario1_horizon_in_paper_ballpark() {
        // Paper Table II: ResNet101, J=10 → T=294 at |S_t|=180ms;
        // VGG19, J=10 → T=176 at 550ms. Accept the right order of magnitude.
        let t_avg = |model: Model, slot: f64| -> f64 {
            let mut acc = 0.0;
            for seed in 0..5u64 {
                let inst = ScenarioCfg::new(Scenario::S1, model, 10, 2, 1000 + seed).generate().quantize(slot);
                acc += inst.horizon() as f64;
            }
            acc / 5.0
        };
        let t_res = t_avg(Model::ResNet101, 180.0);
        assert!((120.0..750.0).contains(&t_res), "T(resnet)={t_res}");
        let t_vgg = t_avg(Model::Vgg19, 550.0);
        assert!((40.0..450.0).contains(&t_vgg), "T(vgg)={t_vgg}");
    }

    #[test]
    fn memory_always_repairable() {
        prop::check(60, |rng| {
            let j = rng.range_usize(1, 40);
            let i = rng.range_usize(1, 8);
            let scen = if rng.chance(0.5) { Scenario::S1 } else { Scenario::S2 };
            let model = if rng.chance(0.5) { Model::ResNet101 } else { Model::Vgg19 };
            let inst = ScenarioCfg::new(scen, model, j, i, rng.next_u64()).generate();
            // validate() ran inside generate(); check capacity slack too.
            let demand: f64 = inst.d_gb.iter().sum();
            let cap: f64 = inst.mem_gb.iter().sum();
            prop::assert_prop(cap >= 1.1 * demand, "capacity covers demand");
        });
    }

    #[test]
    fn scenario2_more_heterogeneous_than_scenario1() {
        // Coefficient of variation of p_ms should be larger in S2.
        let cv = |scen: Scenario| -> f64 {
            let mut cvs = vec![];
            for seed in 0..8u64 {
                let inst = ScenarioCfg::new(scen, Model::ResNet101, 20, 5, 77 + seed).generate();
                let m = inst.p_ms.iter().sum::<f64>() / inst.p_ms.len() as f64;
                let v = inst.p_ms.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / inst.p_ms.len() as f64;
                cvs.push(v.sqrt() / m);
            }
            cvs.iter().sum::<f64>() / cvs.len() as f64
        };
        assert!(cv(Scenario::S2) > cv(Scenario::S1));
    }

    #[test]
    fn scenario2_random_cuts_vary_footprints() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 3).generate();
        let min = inst.d_gb.iter().cloned().fold(f64::MAX, f64::min);
        let max = inst.d_gb.iter().cloned().fold(0.0, f64::max);
        assert!(max > min * 1.05, "footprints should differ: {min}..{max}");
    }

    #[test]
    fn switch_cost_propagates() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 4, 2, 9)
            .with_switch_cost(120.0)
            .generate();
        assert!(inst.mu_ms.iter().all(|&m| (m - 120.0).abs() < 1e-9));
    }
}
