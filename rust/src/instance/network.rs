//! Wireless link model for the client↔helper bipartite network.
//!
//! The paper draws transmission times from "findings on Internet
//! connectivity in France" (Akamai State-of-the-Internet Q4'16): average
//! ~10-15 Mbps downstream with a heavy right tail, a few Mbps upstream.
//! We model each (client, helper) link with a symmetric effective rate
//! ω_ij (the paper assumes symmetric, non-interfering links) drawn from a
//! lognormal around a scenario-dependent median, clamped to a plausible
//! range. The delay to ship `mb` megabytes over link (i,j) is then
//! `mb * 8 / rate_mbps * 1000` ms plus a small per-message RTT overhead.

use crate::util::rng::Rng;

/// Parameters of the link-rate distribution.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Median effective rate in Mbps.
    pub median_mbps: f64,
    /// Lognormal spread (σ of underlying normal). 0 = homogeneous links.
    pub sigma_log: f64,
    /// Clamp range, Mbps.
    pub min_mbps: f64,
    pub max_mbps: f64,
    /// Fixed per-transfer overhead (connection/RTT), ms.
    pub overhead_ms: f64,
}

impl LinkModel {
    /// Akamai-France-like residential links (Scenario 1: modest spread).
    pub fn france_q4_2016() -> LinkModel {
        LinkModel { median_mbps: 10.8, sigma_log: 0.35, min_mbps: 2.0, max_mbps: 60.0, overhead_ms: 20.0 }
    }

    /// High-heterogeneity variant (Scenario 2: wider spread, slower tail).
    pub fn heterogeneous() -> LinkModel {
        LinkModel { median_mbps: 10.8, sigma_log: 0.8, min_mbps: 1.0, max_mbps: 100.0, overhead_ms: 20.0 }
    }

    /// Cellular-like regime (s3-clustered): lower median rate, moderate
    /// spread, and a noticeably longer per-transfer RTT overhead.
    pub fn cellular() -> LinkModel {
        LinkModel { median_mbps: 6.0, sigma_log: 0.55, min_mbps: 0.5, max_mbps: 30.0, overhead_ms: 45.0 }
    }

    /// Every link at exactly `mbps` (σ = 0): the homogeneous limit used by
    /// s6-mega-homogeneous.
    pub fn uniform(mbps: f64) -> LinkModel {
        LinkModel { median_mbps: mbps, sigma_log: 0.0, min_mbps: mbps, max_mbps: mbps, overhead_ms: 20.0 }
    }

    /// Draw an I×J matrix of symmetric link rates (Mbps), row-major by
    /// helper: `rates[i * n_clients + j]`.
    pub fn draw_rates(&self, rng: &mut Rng, n_helpers: usize, n_clients: usize) -> Vec<f64> {
        (0..n_helpers * n_clients)
            .map(|_| self.draw_rate(rng))
            .collect()
    }

    pub fn draw_rate(&self, rng: &mut Rng) -> f64 {
        rng.lognormal_median(self.median_mbps, self.sigma_log).clamp(self.min_mbps, self.max_mbps)
    }

    /// Transfer time in ms for `mb` megabytes at `rate_mbps`.
    pub fn transfer_ms(&self, mb: f64, rate_mbps: f64) -> f64 {
        debug_assert!(rate_mbps > 0.0);
        self.overhead_ms + mb * 8.0 / rate_mbps * 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_within_clamp() {
        let lm = LinkModel::heterogeneous();
        let mut rng = Rng::seeded(3);
        for _ in 0..5_000 {
            let r = lm.draw_rate(&mut rng);
            assert!(r >= lm.min_mbps && r <= lm.max_mbps);
        }
    }

    #[test]
    fn transfer_scales_linearly() {
        let lm = LinkModel::france_q4_2016();
        let t1 = lm.transfer_ms(10.0, 10.0) - lm.overhead_ms;
        let t2 = lm.transfer_ms(20.0, 10.0) - lm.overhead_ms;
        assert!((t2 - 2.0 * t1).abs() < 1e-9);
        // 10 MB at 10 Mbps = 8 seconds.
        assert!((t1 - 8000.0).abs() < 1e-9);
    }

    #[test]
    fn scenario2_has_wider_spread() {
        let mut rng1 = Rng::seeded(5);
        let mut rng2 = Rng::seeded(5);
        let draw = |lm: &LinkModel, rng: &mut Rng| -> f64 {
            let xs: Vec<f64> = (0..2000).map(|_| lm.draw_rate(rng)).collect();
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
        };
        let s1 = draw(&LinkModel::france_q4_2016(), &mut rng1);
        let s2 = draw(&LinkModel::heterogeneous(), &mut rng2);
        assert!(s2 > s1);
    }

    #[test]
    fn uniform_links_have_zero_spread() {
        let lm = LinkModel::uniform(12.0);
        let mut rng = Rng::seeded(9);
        for _ in 0..200 {
            assert!((lm.draw_rate(&mut rng) - 12.0).abs() < 1e-9);
        }
    }

    #[test]
    fn cellular_slower_with_higher_overhead_than_france() {
        let cell = LinkModel::cellular();
        let fr = LinkModel::france_q4_2016();
        assert!(cell.median_mbps < fr.median_mbps);
        assert!(cell.overhead_ms > fr.overhead_ms);
    }

    #[test]
    fn matrix_shape() {
        let lm = LinkModel::france_q4_2016();
        let mut rng = Rng::seeded(7);
        assert_eq!(lm.draw_rates(&mut rng, 3, 5).len(), 15);
    }
}
