//! Problem instances for the joint assignment + scheduling problem ℙ.
//!
//! An [`InstanceMs`] carries the *continuous* (millisecond) delay
//! parameters of the paper's system model (§III): per-edge (helper i,
//! client j) delays r, p, l, l', p', r', per-client helper-memory
//! footprints d_j and per-helper memory capacities m_i. Instances are
//! produced by the scenario generators ([`scenario`]) from the testbed
//! profile bank ([`profiles`]) and the link model ([`network`]).
//!
//! Solvers operate on a *slotted* [`Instance`] obtained via
//! [`InstanceMs::quantize`] for a given slot length |S_t| — exactly the
//! time-slotted model of §III. Keeping the ms-level truth separate from
//! the slotted view lets the Fig-6 experiment quantize the *same* system
//! at 200/150/50 ms and lets the simulator replay slotted schedules in
//! continuous time.

pub mod network;
pub mod profiles;
pub mod scenario;

use crate::util::json::Json;

/// Continuous-time (milliseconds) instance of the parallel-SL system.
///
/// Edge-indexed vectors are row-major by helper: index `i * n_clients + j`.
#[derive(Clone, Debug)]
pub struct InstanceMs {
    pub n_clients: usize,
    pub n_helpers: usize,
    /// Client fwd part-1 + uplink of σ1 activations (release time), ms.
    pub r_ms: Vec<f64>,
    /// Downlink of σ2 activations + client part-3 fwd + loss, ms.
    pub l_ms: Vec<f64>,
    /// Client part-3 bwd + uplink of σ2 gradients, ms.
    pub lp_ms: Vec<f64>,
    /// Downlink of σ1 gradients + client part-1 bwd, ms.
    pub rp_ms: Vec<f64>,
    /// Helper part-2 fwd processing, ms.
    pub p_ms: Vec<f64>,
    /// Helper part-2 bwd processing, ms.
    pub pp_ms: Vec<f64>,
    /// Helper-memory footprint of client j's part-2 task, GB.
    pub d_gb: Vec<f64>,
    /// Helper memory capacity, GB.
    pub mem_gb: Vec<f64>,
    /// Per-helper task-switching (preemption) cost, ms (§VI extension).
    pub mu_ms: Vec<f64>,
    /// Human-readable provenance (scenario, model, seed).
    pub label: String,
}

impl InstanceMs {
    #[inline]
    pub fn edge(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.n_helpers && j < self.n_clients);
        i * self.n_clients + j
    }

    /// Quantize to integer slots of length `slot_ms` (paper §III/§VII).
    /// Processing times are `ceil` (a task occupies whole slots) with a
    /// 1-slot minimum; transmission/client-side delays are `ceil` and may
    /// be 0 when negligible.
    pub fn quantize(&self, slot_ms: f64) -> Instance {
        assert!(slot_ms > 0.0);
        let q = |v: &Vec<f64>, min1: bool| -> Vec<u32> {
            v.iter()
                .map(|&ms| {
                    let s = (ms / slot_ms).ceil() as u32;
                    if min1 { s.max(1) } else { s }
                })
                .collect()
        };
        Instance {
            n_clients: self.n_clients,
            n_helpers: self.n_helpers,
            slot_ms,
            r: q(&self.r_ms, false),
            l: q(&self.l_ms, false),
            lp: q(&self.lp_ms, false),
            rp: q(&self.rp_ms, false),
            p: q(&self.p_ms, true),
            pp: q(&self.pp_ms, true),
            d: self.d_gb.clone(),
            mem: self.mem_gb.clone(),
            mu: self.mu_ms.iter().map(|&ms| (ms / slot_ms).ceil() as u32).collect(),
            label: self.label.clone(),
        }
    }

    /// Serialize to JSON (for `psl gen --out` / golden files).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_clients", Json::Num(self.n_clients as f64)),
            ("n_helpers", Json::Num(self.n_helpers as f64)),
            ("r_ms", Json::arr_f64(&self.r_ms)),
            ("l_ms", Json::arr_f64(&self.l_ms)),
            ("lp_ms", Json::arr_f64(&self.lp_ms)),
            ("rp_ms", Json::arr_f64(&self.rp_ms)),
            ("p_ms", Json::arr_f64(&self.p_ms)),
            ("pp_ms", Json::arr_f64(&self.pp_ms)),
            ("d_gb", Json::arr_f64(&self.d_gb)),
            ("mem_gb", Json::arr_f64(&self.mem_gb)),
            ("mu_ms", Json::arr_f64(&self.mu_ms)),
            ("label", Json::Str(self.label.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<InstanceMs> {
        let vec_f64 = |key: &str| -> anyhow::Result<Vec<f64>> {
            v.get(key)
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("missing array {key}"))?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("non-number in {key}")))
                .collect()
        };
        let inst = InstanceMs {
            n_clients: v.get("n_clients").as_usize().ok_or_else(|| anyhow::anyhow!("n_clients"))?,
            n_helpers: v.get("n_helpers").as_usize().ok_or_else(|| anyhow::anyhow!("n_helpers"))?,
            r_ms: vec_f64("r_ms")?,
            l_ms: vec_f64("l_ms")?,
            lp_ms: vec_f64("lp_ms")?,
            rp_ms: vec_f64("rp_ms")?,
            p_ms: vec_f64("p_ms")?,
            pp_ms: vec_f64("pp_ms")?,
            d_gb: vec_f64("d_gb")?,
            mem_gb: vec_f64("mem_gb")?,
            mu_ms: vec_f64("mu_ms")?,
            label: v.get("label").as_str().unwrap_or("").to_string(),
        };
        inst.validate()?;
        Ok(inst)
    }

    /// Project the instance onto a subset of clients (churn rounds,
    /// what-if analyses). `keep` holds original client indices, in the
    /// order the projected instance should use. Helpers are unchanged.
    /// An empty `keep` yields a valid zero-client instance — full-
    /// departure fleet rounds must not abort a run.
    pub fn restrict_clients(&self, keep: &[usize]) -> InstanceMs {
        assert!(keep.iter().all(|&j| j < self.n_clients), "client index out of range");
        let pick = |v: &Vec<f64>| -> Vec<f64> {
            let mut out = Vec::with_capacity(self.n_helpers * keep.len());
            for i in 0..self.n_helpers {
                for &j in keep {
                    out.push(v[i * self.n_clients + j]);
                }
            }
            out
        };
        let inst = InstanceMs {
            n_clients: keep.len(),
            n_helpers: self.n_helpers,
            r_ms: pick(&self.r_ms),
            l_ms: pick(&self.l_ms),
            lp_ms: pick(&self.lp_ms),
            rp_ms: pick(&self.rp_ms),
            p_ms: pick(&self.p_ms),
            pp_ms: pick(&self.pp_ms),
            d_gb: keep.iter().map(|&j| self.d_gb[j]).collect(),
            mem_gb: self.mem_gb.clone(),
            mu_ms: self.mu_ms.clone(),
            label: format!("{} [J'={}]", self.label, keep.len()),
        };
        inst.validate().expect("restriction preserves validity");
        inst
    }

    /// Project the instance onto a subset of helpers (the shard layer's
    /// helper cells). `keep` holds original helper indices, in the order
    /// the projected instance should use. Clients are unchanged — pair
    /// with [`restrict_clients`](Self::restrict_clients) to carve out a
    /// full sub-instance. Callers must leave every remaining client a
    /// feasible helper (the shard partitioner's memory fix-up guarantees
    /// this); the debug-path validation enforces it.
    pub fn restrict_helpers(&self, keep: &[usize]) -> InstanceMs {
        assert!(keep.iter().all(|&i| i < self.n_helpers), "helper index out of range");
        let pick = |v: &Vec<f64>| -> Vec<f64> {
            let mut out = Vec::with_capacity(keep.len() * self.n_clients);
            for &i in keep {
                out.extend_from_slice(&v[i * self.n_clients..(i + 1) * self.n_clients]);
            }
            out
        };
        let inst = InstanceMs {
            n_clients: self.n_clients,
            n_helpers: keep.len(),
            r_ms: pick(&self.r_ms),
            l_ms: pick(&self.l_ms),
            lp_ms: pick(&self.lp_ms),
            rp_ms: pick(&self.rp_ms),
            p_ms: pick(&self.p_ms),
            pp_ms: pick(&self.pp_ms),
            d_gb: self.d_gb.clone(),
            mem_gb: keep.iter().map(|&i| self.mem_gb[i]).collect(),
            mu_ms: keep.iter().map(|&i| self.mu_ms[i]).collect(),
            label: format!("{} [I'={}]", self.label, keep.len()),
        };
        inst.validate().expect("helper restriction must keep every client a feasible helper");
        inst
    }

    /// Structural sanity: vector lengths, positivity, memory feasibility.
    pub fn validate(&self) -> anyhow::Result<()> {
        let e = self.n_clients * self.n_helpers;
        for (name, v) in [
            ("r_ms", &self.r_ms),
            ("l_ms", &self.l_ms),
            ("lp_ms", &self.lp_ms),
            ("rp_ms", &self.rp_ms),
            ("p_ms", &self.p_ms),
            ("pp_ms", &self.pp_ms),
        ] {
            anyhow::ensure!(v.len() == e, "{name}: len {} != {e}", v.len());
            anyhow::ensure!(v.iter().all(|x| x.is_finite() && *x >= 0.0), "{name}: negative/NaN entry");
        }
        anyhow::ensure!(self.d_gb.len() == self.n_clients, "d_gb length");
        anyhow::ensure!(self.mem_gb.len() == self.n_helpers, "mem_gb length");
        anyhow::ensure!(self.mu_ms.len() == self.n_helpers, "mu_ms length");
        anyhow::ensure!(self.p_ms.iter().all(|&x| x > 0.0), "p_ms must be positive");
        anyhow::ensure!(self.pp_ms.iter().all(|&x| x > 0.0), "pp_ms must be positive");
        // Every client must fit on at least one helper.
        let max_mem = self.mem_gb.iter().cloned().fold(0.0, f64::max);
        for (j, &d) in self.d_gb.iter().enumerate() {
            anyhow::ensure!(d <= max_mem, "client {j} (d={d} GB) fits no helper (max m={max_mem})");
        }
        Ok(())
    }
}

/// Slot-quantized instance: the solvers' world. All delays in integer
/// slots of length `slot_ms`. Edge index: `i * n_clients + j`.
#[derive(Clone, Debug)]
pub struct Instance {
    pub n_clients: usize,
    pub n_helpers: usize,
    pub slot_ms: f64,
    pub r: Vec<u32>,
    pub l: Vec<u32>,
    pub lp: Vec<u32>,
    pub rp: Vec<u32>,
    pub p: Vec<u32>,
    pub pp: Vec<u32>,
    pub d: Vec<f64>,
    pub mem: Vec<f64>,
    pub mu: Vec<u32>,
    pub label: String,
}

impl Instance {
    #[inline]
    pub fn edge(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.n_helpers && j < self.n_clients, "edge({i},{j})");
        i * self.n_clients + j
    }

    /// The paper's horizon bound T (§III): worst-case client-side round
    /// trip plus the sum over clients of the worst helper-processing time.
    pub fn horizon(&self) -> u32 {
        let mut worst_rt = 0u32;
        for e in 0..self.r.len() {
            worst_rt = worst_rt.max(self.r[e] + self.l[e] + self.lp[e] + self.rp[e]);
        }
        let mut sum_proc = 0u32;
        for j in 0..self.n_clients {
            let mut m = 0u32;
            for i in 0..self.n_helpers {
                let e = self.edge(i, j);
                m = m.max(self.p[e] + self.pp[e]);
            }
            sum_proc += m;
        }
        worst_rt + sum_proc
    }

    /// Fwd-only horizon T_f (§V-A): max (r + l) + Σ_j max_i p_ij.
    pub fn horizon_fwd(&self) -> u32 {
        let mut worst = 0u32;
        for e in 0..self.r.len() {
            worst = worst.max(self.r[e] + self.l[e]);
        }
        let mut sum_p = 0u32;
        for j in 0..self.n_clients {
            let mut m = 0u32;
            for i in 0..self.n_helpers {
                m = m.max(self.p[self.edge(i, j)]);
            }
            sum_p += m;
        }
        worst + sum_p
    }

    /// Trivial makespan lower bound: every client must at least traverse
    /// its best edge end-to-end; every helper's load is ≥ 0.
    pub fn makespan_lower_bound(&self) -> u32 {
        let mut lb = 0u32;
        for j in 0..self.n_clients {
            let mut best = u32::MAX;
            for i in 0..self.n_helpers {
                let e = self.edge(i, j);
                best = best.min(self.r[e] + self.p[e] + self.l[e] + self.lp[e] + self.pp[e] + self.rp[e]);
            }
            lb = lb.max(best);
        }
        lb
    }

    /// Helpers that can hold client j alone (m_i ≥ d_j).
    pub fn feasible_helpers(&self, j: usize) -> Vec<usize> {
        (0..self.n_helpers).filter(|&i| self.mem[i] >= self.d[j]).collect()
    }

    /// Quantization-stable lift back to the continuous domain: the shard
    /// layer partitions an already-quantized instance with the ms-level
    /// projections ([`InstanceMs::restrict_clients`] /
    /// [`InstanceMs::restrict_helpers`]) and re-quantizes each cell, so
    /// `inst.to_ms().quantize(inst.slot_ms)` must reproduce `inst`
    /// **exactly** — otherwise a stitched schedule could violate the
    /// original slot counts. Each `s`-slot delay lifts to the midpoint
    /// `(s - ½)·|S_t|` rather than `s·|S_t|`: `ceil` of the midpoint is
    /// robustly `s` under floating-point division, while `ceil(s·|S_t| /
    /// |S_t|)` can land on `s + 1` when the quotient rounds up. Zero-slot
    /// delays stay 0; the 1-slot processing minimum is preserved by the
    /// same midpoint argument.
    pub fn to_ms(&self) -> InstanceMs {
        let lift = |v: &Vec<u32>| -> Vec<f64> {
            v.iter().map(|&s| (s as f64 - 0.5).max(0.0) * self.slot_ms).collect()
        };
        let ms = InstanceMs {
            n_clients: self.n_clients,
            n_helpers: self.n_helpers,
            r_ms: lift(&self.r),
            l_ms: lift(&self.l),
            lp_ms: lift(&self.lp),
            rp_ms: lift(&self.rp),
            p_ms: lift(&self.p),
            pp_ms: lift(&self.pp),
            d_gb: self.d.clone(),
            mem_gb: self.mem.clone(),
            mu_ms: lift(&self.mu),
            label: self.label.clone(),
        };
        debug_assert!(ms.validate().is_ok());
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::scenario::{Scenario, ScenarioCfg};
    use super::profiles::Model;

    fn small() -> super::InstanceMs {
        ScenarioCfg::new(Scenario::S1, Model::ResNet101, 6, 2, 42).generate()
    }

    #[test]
    fn quantize_monotone_in_slot_len() {
        let ms = small();
        let a = ms.quantize(50.0);
        let b = ms.quantize(200.0);
        // Finer slots → more slots per task.
        for e in 0..a.p.len() {
            assert!(a.p[e] >= b.p[e]);
        }
        // But ms-equivalents bracket the true value from above.
        for e in 0..a.p.len() {
            assert!(a.p[e] as f64 * 50.0 >= ms.p_ms[e] - 1e-9);
            assert!(b.p[e] as f64 * 200.0 >= ms.p_ms[e] - 1e-9);
        }
    }

    #[test]
    fn horizon_bounds_make_sense() {
        let inst = small().quantize(180.0);
        assert!(inst.horizon() >= inst.horizon_fwd());
        assert!(inst.horizon() as u32 >= inst.makespan_lower_bound());
    }

    #[test]
    fn json_roundtrip() {
        let ms = small();
        let j = ms.to_json();
        let back = super::InstanceMs::from_json(&j).unwrap();
        assert_eq!(back.n_clients, ms.n_clients);
        assert_eq!(back.p_ms, ms.p_ms);
        assert_eq!(back.mem_gb, ms.mem_gb);
    }

    #[test]
    fn validate_catches_bad_lengths() {
        let mut ms = small();
        ms.p_ms.pop();
        assert!(ms.validate().is_err());
    }

    #[test]
    fn restrict_clients_projects_edges() {
        let ms = small(); // 6 clients, 2 helpers
        let sub = ms.restrict_clients(&[0, 2, 5]);
        assert_eq!(sub.n_clients, 3);
        assert_eq!(sub.n_helpers, 2);
        for i in 0..2 {
            for (jj, &j) in [0usize, 2, 5].iter().enumerate() {
                assert_eq!(sub.p_ms[i * 3 + jj], ms.p_ms[i * 6 + j]);
                assert_eq!(sub.r_ms[i * 3 + jj], ms.r_ms[i * 6 + j]);
            }
        }
        assert_eq!(sub.d_gb, vec![ms.d_gb[0], ms.d_gb[2], ms.d_gb[5]]);
        assert_eq!(sub.mem_gb, ms.mem_gb);
    }

    #[test]
    fn restrict_clients_empty_is_valid() {
        // Full-departure fleet rounds project onto zero clients; that must
        // be a valid (empty) instance, not a panic.
        let sub = small().restrict_clients(&[]);
        assert_eq!(sub.n_clients, 0);
        assert_eq!(sub.n_helpers, 2);
        assert!(sub.p_ms.is_empty() && sub.d_gb.is_empty());
        assert_eq!(sub.mem_gb, small().mem_gb, "helpers unchanged");
        assert!(sub.validate().is_ok());
        assert_eq!(sub.quantize(180.0).horizon(), 0);
    }

    #[test]
    fn restrict_helpers_projects_rows() {
        let ms = small(); // 6 clients, 2 helpers
        let sub = ms.restrict_helpers(&[1]);
        assert_eq!(sub.n_clients, 6);
        assert_eq!(sub.n_helpers, 1);
        for j in 0..6 {
            assert_eq!(sub.p_ms[j], ms.p_ms[6 + j]);
            assert_eq!(sub.r_ms[j], ms.r_ms[6 + j]);
            assert_eq!(sub.lp_ms[j], ms.lp_ms[6 + j]);
        }
        assert_eq!(sub.d_gb, ms.d_gb);
        assert_eq!(sub.mem_gb, vec![ms.mem_gb[1]]);
        assert_eq!(sub.mu_ms, vec![ms.mu_ms[1]]);
    }

    #[test]
    fn restrict_helpers_then_clients_commute() {
        let ms = small();
        let a = ms.restrict_helpers(&[0]).restrict_clients(&[1, 3]);
        let b = ms.restrict_clients(&[1, 3]).restrict_helpers(&[0]);
        assert_eq!(a.p_ms, b.p_ms);
        assert_eq!(a.r_ms, b.r_ms);
        assert_eq!(a.d_gb, b.d_gb);
        assert_eq!(a.mem_gb, b.mem_gb);
    }

    #[test]
    fn to_ms_quantize_roundtrips_exactly() {
        // The shard layer depends on this being *exact*, including at slot
        // lengths whose reciprocal is not a power of two.
        for scenario in [Scenario::S1, Scenario::S2, Scenario::S4StragglerTail] {
            for slot_ms in [0.1, 50.0, 180.0, 187.5, 550.0] {
                let inst = ScenarioCfg::new(scenario, Model::ResNet101, 10, 3, 7)
                    .generate()
                    .quantize(slot_ms);
                let back = inst.to_ms().quantize(slot_ms);
                assert_eq!(back.r, inst.r, "slot {slot_ms}");
                assert_eq!(back.l, inst.l);
                assert_eq!(back.lp, inst.lp);
                assert_eq!(back.rp, inst.rp);
                assert_eq!(back.p, inst.p);
                assert_eq!(back.pp, inst.pp);
                assert_eq!(back.mu, inst.mu);
                assert_eq!(back.d, inst.d);
                assert_eq!(back.mem, inst.mem);
            }
        }
    }

    #[test]
    fn processing_slots_at_least_one() {
        let inst = small().quantize(10_000.0);
        assert!(inst.p.iter().all(|&x| x >= 1));
        assert!(inst.pp.iter().all(|&x| x >= 1));
    }
}
