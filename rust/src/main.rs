//! `psl` — the leader binary: CLI over the coordinator.
//! See `psl help` (or [`psl::cli::HELP`]).

use anyhow::{Context, Result};
use psl::cli::{Args, HELP};
use psl::coordinator::{compare_methods, SolveRequest, TrainRequest};
use psl::instance::profiles::{Device, Model, DEVICES};
use psl::instance::scenario::Scenario;
use psl::sim;
use psl::slexec::TrainCfg;
use psl::solver::admm::AdmmCfg;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn solve_request(args: &Args) -> Result<SolveRequest> {
    let scenario = Scenario::parse(&args.str_of("scenario", "1")).context("bad --scenario")?;
    let model = Model::parse(&args.str_of("model", "resnet101")).context("bad --model")?;
    Ok(SolveRequest {
        scenario,
        model,
        n_clients: args.usize_of("j", 10),
        n_helpers: args.usize_of("i", 2),
        seed: args.u64_of("seed", 42),
        slot_ms: args.flags.get("slot-ms").and_then(|v| v.parse().ok()),
        switch_cost_ms: args.f64_of("switch-cost", 0.0),
    })
}

fn dispatch(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "" | "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "profiles" => cmd_profiles(),
        "gen" => cmd_gen(args),
        "solve" => with_trace(args, false, cmd_solve),
        "sweep-slots" => cmd_sweep(args),
        "sweep" => cmd_sweep_grid(args),
        "fleet" => with_trace(args, false, cmd_fleet),
        "serve" => with_trace(args, true, cmd_serve),
        "perf" => cmd_perf(args),
        "shard" => with_trace(args, false, cmd_shard),
        "analyze" => cmd_analyze(args),
        "train" => cmd_train(args),
        other => anyhow::bail!("unknown command {other:?}; see `psl help`"),
    }
}

fn cmd_profiles() -> Result<()> {
    println!("Table I — testbed devices, whole-batch update time (batch 128):");
    println!("  {:<28} {:>12} {:>10} {:>7} {:>7}", "device", "ResNet101[s]", "VGG19[s]", "RAM", "helper");
    for d in DEVICES {
        let r = d.device.batch_ms(Model::ResNet101) / 1000.0;
        let v = d.device.batch_ms(Model::Vgg19) / 1000.0;
        println!(
            "  {:<28} {:>12.1} {:>10.1} {:>6.0}G {:>7}",
            d.name,
            r,
            v,
            d.ram_gb,
            if d.helper_capable { "yes" } else { "no" }
        );
    }
    println!("\nFig 5 — part-1 compute time per device (default cuts), fwd/bwd ms:");
    for model in [Model::ResNet101, Model::Vgg19] {
        let prof = model.profile();
        let (s1, _) = prof.default_cuts;
        println!("  {} (part-1 = layers 1..{s1}):", prof.name);
        for d in DEVICES {
            let (f, b) = d.device.range_fwd_bwd_ms(model, 1, s1);
            println!("    {:<28} fwd {:>9.1}  bwd {:>9.1}", d.name, f, b);
        }
    }
    let _ = Device::client_pool();
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let req = solve_request(args)?;
    let ms = req.instance_ms();
    let json = ms.to_json().pretty();
    match args.flags.get("out") {
        Some(path) => {
            std::fs::write(path, &json)?;
            println!("wrote {} ({} clients, {} helpers)", path, ms.n_clients, ms.n_helpers);
        }
        None => println!("{json}"),
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let req = solve_request(args)?;
    let method = args.str_of("method", "all");
    let replay = args.bool_of("replay");
    let ms = req.instance_ms();
    let inst = ms.quantize(req.slot_ms());
    println!(
        "instance: {} | T={} slots | slot {} ms | heterogeneity CV {:.2}",
        inst.label,
        inst.horizon(),
        inst.slot_ms,
        psl::solver::strategy::heterogeneity(&inst)
    );
    let rows = if method == "all" {
        compare_methods(&req, args.bool_of("exact"), replay)?
    } else {
        vec![psl::coordinator::run_method(&ms, &inst, &method, replay, req.seed)?]
    };
    println!(
        "  {:<10} {:>10} {:>12} {:>12} {:>9} {:>6}",
        "method", "slots", "nominal[s]", "realized[s]", "solve", "preempt"
    );
    for r in &rows {
        println!(
            "  {:<10} {:>10} {:>12.1} {:>12} {:>9} {:>6}",
            r.method,
            r.makespan_slots,
            r.makespan_ms / 1000.0,
            r.realized_ms.map(|v| format!("{:.1}", v / 1000.0)).unwrap_or_else(|| "-".into()),
            psl::bench::fmt_s(r.solve_s),
            r.preemptions
        );
    }
    if let Some(path) = args.flags.get("gantt") {
        let best = rows.iter().min_by_key(|r| r.makespan_slots).context("no methods ran")?;
        let schedule = match best.method.as_str() {
            "greedy" => psl::solver::greedy::solve(&inst).unwrap(),
            _ => psl::solver::strategy::solve(&inst, &AdmmCfg::default()).unwrap().0,
        };
        std::fs::write(path, sim::gantt_json(&inst, &schedule).pretty())?;
        println!("gantt → {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let req = solve_request(args)?;
    let ms = req.instance_ms();
    let slots: Vec<f64> = args
        .str_of("slots", "200,150,50")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let rows = sim::quantize::sweep_slot_lengths(&ms, &slots, &AdmmCfg::default());
    println!("  {:>8} {:>8} {:>12} {:>13} {:>9} {:>8}", "slot[ms]", "T", "nominal[s]", "realized[s]", "solve", "preempt");
    for r in rows {
        println!(
            "  {:>8.0} {:>8} {:>12.1} {:>13.1} {:>9} {:>8}",
            r.slot_ms,
            r.horizon,
            r.nominal_ms / 1000.0,
            r.realized_ms / 1000.0,
            psl::bench::fmt_s(r.solve_s),
            r.preemptions
        );
    }
    Ok(())
}

/// `psl sweep --diff <old.json> <new.json>`: cell-by-cell makespan
/// comparison of two sweep artifacts; non-zero exit on any regression
/// beyond `--tol` (relative, default 2%).
fn cmd_sweep_diff(args: &Args, old_path: &str) -> Result<()> {
    let new_path = args
        .positional
        .first()
        .context("usage: psl sweep --diff <old.json> <new.json> [--tol X]")?;
    let tol: f64 = parsed_flag(args, "tol", 0.02)?;
    anyhow::ensure!(tol >= 0.0, "--tol must be non-negative, got {tol}");
    // Load through the artifact registry (envelope-checked); the diff
    // itself re-pins the sweep kind.
    let load = |path: &str| -> Result<psl::util::json::Json> {
        Ok(psl::bench::artifact::load(path)?.1)
    };
    let report = psl::bench::sweep::diff_documents(&load(old_path)?, &load(new_path)?, tol)?;
    println!(
        "sweep diff: {} cells compared (tol {:.1}%) | {} improved | {} only-old | {} only-new",
        report.compared,
        tol * 100.0,
        report.improved,
        report.only_old,
        report.only_new
    );
    for r in &report.regressions {
        let fmt = |v: Option<f64>| v.map(|x| format!("{:.1}", x / 1000.0)).unwrap_or_else(|| "infeasible".into());
        println!("  REGRESSION {}: {} s -> {} s", r.cell, fmt(r.old_ms), fmt(r.new_ms));
    }
    if report.regressions.is_empty() {
        println!("no regressions");
        Ok(())
    } else {
        anyhow::bail!("{} cell(s) regressed beyond {:.1}% tolerance", report.regressions.len(), tol * 100.0)
    }
}

/// `--trace FILE` (solve/fleet/shard/serve): run the command inside a
/// process-wide [`Recording`](psl::obs::Recording) and write the capture
/// as a `psl-trace` artifact afterwards. Instrumentation never feeds
/// back into decisions, so every other artifact the command writes is
/// byte-identical with or without it (CI diffs a traced fleet run
/// against an untraced one). `to_stderr` routes the confirmation line to
/// stderr for `serve`, whose stdout is a pure report stream. On error
/// the capture is dropped (discarding it also releases the recording),
/// so no partial trace file is left behind.
fn with_trace(args: &Args, to_stderr: bool, run: fn(&Args) -> Result<()>) -> Result<()> {
    let capture = args
        .flags
        .get("trace")
        .map(|path| (path.clone(), psl::obs::Recording::start()));
    run(args)?;
    if let Some((path, rec)) = capture {
        let data = rec.finish();
        let written = psl::obs::write_trace(&path, &data)?;
        let line = format!(
            "trace -> {} ({} spans, {} counters)",
            written.display(),
            data.spans.len(),
            data.counters.len()
        );
        if to_stderr {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    }
    Ok(())
}

/// Parse an optional flag strictly: absent → default, present-but-
/// malformed → error (a typo'd value must not silently fall back).
fn parsed_flag<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T> {
    match args.flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().ok().with_context(|| format!("bad --{key} {v:?}")),
    }
}

/// `--checkpoint-every N`: absent → None, present → a count ≥ 1.
fn optional_count_flag(args: &Args, key: &str) -> Result<Option<usize>> {
    match args.flags.get(key) {
        None => Ok(None),
        Some(v) => {
            let n: usize = v.parse().ok().with_context(|| format!("bad --{key} {v:?}"))?;
            anyhow::ensure!(n >= 1, "--{key} must be >= 1, got {n}");
            Ok(Some(n))
        }
    }
}

/// A checkpoint records the full run config; letting `--resume` override
/// any of it would silently fork the run from its own history. Reject
/// every recorded knob (only --rounds/--out/--checkpoint-every may
/// accompany --resume).
fn reject_recorded_flags(args: &Args) -> Result<()> {
    for key in [
        "scenario",
        "model",
        "j",
        "i",
        "seed",
        "slot-ms",
        "depart-prob",
        "arrival-rate",
        "max-clients",
        "policy",
        "policy-table",
        "churn-threshold",
        "gap-threshold",
        "batches",
        "helper-down-rate",
        "helper-outage-rounds",
        "helper-join-rate",
        "max-helpers",
        "diurnal-period",
        "capacity-threshold",
        "link-model",
        "uplink-capacity",
    ] {
        anyhow::ensure!(
            !args.flags.contains_key(key),
            "--{key} is recorded in the checkpoint and cannot be overridden on --resume \
             (only --rounds, --out and --checkpoint-every apply)"
        );
    }
    Ok(())
}

/// Transport-model knobs shared by `psl fleet`, `psl serve` and
/// `psl sweep`: `--link-model dedicated|shared` plus the shared pool's
/// `--uplink-capacity`. Absent flags keep the dedicated default — and its
/// byte-identical artifacts.
fn parse_transport_flags(args: &Args) -> Result<psl::transport::TransportCfg> {
    use psl::transport::{LinkMode, TransportCfg, DEFAULT_UPLINK_CAPACITY};
    let mode = match args.flags.get("link-model") {
        None => LinkMode::Dedicated,
        Some(v) => {
            LinkMode::parse(v).with_context(|| format!("bad --link-model {v:?} (dedicated|shared)"))?
        }
    };
    match mode {
        LinkMode::Dedicated => {
            // A capacity on dedicated links would be silently ignored —
            // reject it so the run means what the command line says.
            anyhow::ensure!(
                !args.flags.contains_key("uplink-capacity"),
                "--uplink-capacity needs --link-model shared"
            );
            Ok(TransportCfg::dedicated())
        }
        LinkMode::Shared => {
            let cap: f64 = parsed_flag(args, "uplink-capacity", DEFAULT_UPLINK_CAPACITY)?;
            anyhow::ensure!(
                cap.is_finite() && cap > 0.0,
                "--uplink-capacity must be finite and > 0, got {cap}"
            );
            Ok(TransportCfg::shared(cap))
        }
    }
}

/// Helper-dynamics knobs shared by `psl fleet` and `psl serve`, applied
/// on top of the scenario's default helper model (static for most
/// families, bursts for s7-helper-bursts). Strict validation: a typo'd
/// value errors instead of silently keeping the default.
fn apply_helper_flags(args: &Args, cfg: &mut psl::fleet::FleetCfg) -> Result<()> {
    let mut hc = cfg.helper_churn.clone();
    hc.down_rate = parsed_flag(args, "helper-down-rate", hc.down_rate)?;
    anyhow::ensure!(
        hc.down_rate.is_finite() && (0.0..=1.0).contains(&hc.down_rate),
        "--helper-down-rate must be in [0, 1], got {}",
        hc.down_rate
    );
    hc.outage_rounds = parsed_flag(args, "helper-outage-rounds", hc.outage_rounds)?;
    anyhow::ensure!(hc.outage_rounds >= 1, "--helper-outage-rounds must be >= 1");
    hc.join_rate = parsed_flag(args, "helper-join-rate", hc.join_rate)?;
    anyhow::ensure!(
        hc.join_rate.is_finite() && hc.join_rate >= 0.0,
        "--helper-join-rate must be finite and >= 0, got {}",
        hc.join_rate
    );
    hc.max_helpers = parsed_flag(args, "max-helpers", hc.max_helpers)?;
    hc.diurnal_period = parsed_flag(args, "diurnal-period", hc.diurnal_period)?;
    if hc.join_rate > 0.0 {
        anyhow::ensure!(
            hc.max_helpers > cfg.scenario.n_helpers,
            "--helper-join-rate needs --max-helpers above the base helper count {} (got {})",
            cfg.scenario.n_helpers,
            hc.max_helpers
        );
    }
    cfg.helper_churn = hc;
    cfg.capacity_threshold = parsed_flag(args, "capacity-threshold", cfg.capacity_threshold)?;
    anyhow::ensure!(
        cfg.capacity_threshold.is_finite() && (0.0..=1.0).contains(&cfg.capacity_threshold),
        "--capacity-threshold must be in [0, 1], got {}",
        cfg.capacity_threshold
    );
    Ok(())
}

/// Parse a comma-separated list flag (`--scenarios 1,2,3`) into trimmed,
/// non-empty items.
fn csv_list(args: &Args, key: &str, default: &str) -> Vec<String> {
    args.str_of(key, default)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn cmd_sweep_grid(args: &Args) -> Result<()> {
    if let Some(old_path) = args.flags.get("diff") {
        return cmd_sweep_diff(args, old_path);
    }
    let list = |key: &str, default: &str| csv_list(args, key, default);
    let scenarios = list("scenarios", "1,2,3,4")
        .iter()
        .map(|s| Scenario::parse(s).with_context(|| format!("bad scenario {s:?} in --scenarios")))
        .collect::<Result<Vec<_>>>()?;
    let models = list("models", "resnet101")
        .iter()
        .map(|s| Model::parse(s).with_context(|| format!("bad model {s:?} in --models")))
        .collect::<Result<Vec<_>>>()?;
    let sizes = list("sizes", "10x2,20x5")
        .iter()
        .map(|s| {
            let (j, i) = s.split_once('x').with_context(|| format!("size {s:?} is not JxI"))?;
            let j = j.trim().parse::<usize>().ok().with_context(|| format!("bad J in {s:?}"))?;
            let i = i.trim().parse::<usize>().ok().with_context(|| format!("bad I in {s:?}"))?;
            anyhow::ensure!(j >= 1 && i >= 1, "size {s:?} needs J >= 1 and I >= 1");
            Ok((j, i))
        })
        .collect::<Result<Vec<_>>>()?;
    let seeds = list("seeds", "42")
        .iter()
        .map(|s| s.parse::<u64>().ok().with_context(|| format!("bad seed {s:?}")))
        .collect::<Result<Vec<_>>>()?;
    let methods = list("methods", "admm,greedy");
    for m in &methods {
        anyhow::ensure!(
            matches!(m.as_str(), "admm" | "greedy" | "baseline" | "strategy"),
            "unknown method {m:?} (admm|greedy|baseline|strategy)"
        );
    }
    let slot_ms = match args.flags.get("slot-ms") {
        None => None,
        Some(v) => {
            let ms: f64 = v.parse().ok().with_context(|| format!("bad --slot-ms {v:?}"))?;
            anyhow::ensure!(ms > 0.0, "--slot-ms must be positive, got {ms}");
            Some(ms)
        }
    };
    let cfg = psl::bench::sweep::SweepCfg {
        scenarios,
        models,
        sizes,
        seeds,
        methods,
        slot_ms,
        transport: parse_transport_flags(args)?,
        threads: args.usize_of("threads", psl::exec::pool::default_workers()),
    };
    let n_cells = psl::bench::sweep::cells(&cfg).len();
    let link = if cfg.transport.is_dedicated() {
        String::new()
    } else {
        format!(" | link=shared cap={}", cfg.transport.capacity)
    };
    println!(
        "sweep: {} scenarios x {} models x {} sizes x {} seeds x {} methods = {} cells on {} threads{}",
        cfg.scenarios.len(),
        cfg.models.len(),
        cfg.sizes.len(),
        cfg.seeds.len(),
        cfg.methods.len(),
        n_cells,
        cfg.threads,
        link
    );
    let start = std::time::Instant::now();
    let rows = psl::bench::sweep::run(&cfg);
    let wall = start.elapsed().as_secs_f64();
    println!(
        "  {:<20} {:<10} {:>5} {:>3} {:>6} {:<10} {:>8} {:>12} {:>5} {:>6}",
        "scenario", "model", "J", "I", "seed", "method", "slots", "makespan[s]", "het", "flex"
    );
    for r in &rows {
        println!(
            "  {:<20} {:<10} {:>5} {:>3} {:>6} {:<10} {:>8} {:>12} {:>5.2} {:>6.2}",
            r.scenario,
            r.model,
            r.n_clients,
            r.n_helpers,
            r.seed,
            r.method,
            r.makespan_slots.map(|m| m.to_string()).unwrap_or_else(|| "-".into()),
            r.makespan_ms.map(|m| format!("{:.1}", m / 1000.0)).unwrap_or_else(|| "-".into()),
            r.heterogeneity,
            r.placement_flexibility
        );
    }
    let path = psl::bench::sweep::save(&rows, &args.str_of("out", "sweep"))?;
    println!(
        "{} rows -> {} in {} ({} threads)",
        rows.len(),
        path.display(),
        psl::bench::fmt_s(wall),
        cfg.threads
    );
    Ok(())
}

/// `psl fleet`: one deterministic multi-round churn run (or, with
/// `--grid`, the scenario × churn-rate × policy grid across threads).
/// `--checkpoint-every N` snapshots the session as a resumable
/// `psl-fleet-checkpoint` artifact; `--resume CKPT` continues one to the
/// same final report and sidecars, byte for byte.
fn cmd_fleet(args: &Args) -> Result<()> {
    use psl::fleet::{ChurnCfg, FleetCfg, FleetCheckpoint, FleetSession, Policy};
    if args.bool_of("grid") {
        return cmd_fleet_grid(args);
    }
    let checkpoint_every = optional_count_flag(args, "checkpoint-every")?;
    let mut session = if let Some(ckpt_path) = args.flags.get("resume") {
        reject_recorded_flags(args)?;
        let mut session = FleetSession::resume(FleetCheckpoint::load(ckpt_path)?)?;
        if let Some(v) = args.flags.get("rounds") {
            let rounds: usize = v.parse().ok().with_context(|| format!("bad --rounds {v:?}"))?;
            session.extend_rounds(rounds)?;
        }
        // A serve-produced checkpoint may sit past its recorded horizon
        // (serve ignores `rounds`); never regenerate a stream shorter
        // than the cursor.
        let horizon = session.cfg().churn.rounds.max(session.next_round());
        session.extend_rounds(horizon)?;
        session
    } else {
        let scenario = Scenario::parse(&args.str_of("scenario", "4")).context("bad --scenario")?;
        let model = Model::parse(&args.str_of("model", "resnet101")).context("bad --model")?;
        let j = args.usize_of("j", 10);
        let i = args.usize_of("i", 2);
        anyhow::ensure!(j >= 1 && i >= 1, "fleet needs -j >= 1 and -i >= 1");
        let rounds: usize = parsed_flag(args, "rounds", 8)?;
        anyhow::ensure!(rounds >= 1, "--rounds must be >= 1");
        let policy = Policy::parse(&args.str_of("policy", "incremental"))
            .context("bad --policy (incremental|full|repair-only|auto)")?;
        // Start from the tested stationary defaults, then apply overrides.
        let mut churn = ChurnCfg::stationary(j);
        churn.rounds = rounds;
        churn.departure_prob = parsed_flag(args, "depart-prob", churn.departure_prob)?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&churn.departure_prob),
            "--depart-prob must be in [0, 1], got {}",
            churn.departure_prob
        );
        churn.arrival_rate = match args.flags.get("arrival-rate") {
            Some(v) => v.parse().ok().with_context(|| format!("bad --arrival-rate {v:?}"))?,
            // Stationary default: expected arrivals balance expected departures.
            None => churn.departure_prob * j as f64,
        };
        anyhow::ensure!(
            churn.arrival_rate >= 0.0 && churn.arrival_rate.is_finite(),
            "--arrival-rate must be finite and >= 0, got {}",
            churn.arrival_rate
        );
        churn.max_clients = parsed_flag(args, "max-clients", churn.max_clients)?;
        let scen = psl::instance::scenario::ScenarioCfg::new(scenario, model, j, i, args.u64_of("seed", 42));
        let mut cfg = FleetCfg::new(scen, churn, policy);
        cfg.slot_ms = match args.flags.get("slot-ms") {
            None => None,
            Some(v) => {
                let ms: f64 = v.parse().ok().with_context(|| format!("bad --slot-ms {v:?}"))?;
                anyhow::ensure!(ms > 0.0, "--slot-ms must be positive, got {ms}");
                Some(ms)
            }
        };
        cfg.churn_threshold = parsed_flag(args, "churn-threshold", cfg.churn_threshold)?;
        cfg.gap_threshold = parsed_flag(args, "gap-threshold", cfg.gap_threshold)?;
        cfg.epoch_batches = parsed_flag(args, "batches", cfg.epoch_batches)?;
        apply_helper_flags(args, &mut cfg)?;
        cfg.transport = parse_transport_flags(args)?;
        if let Some(table_path) = args.flags.get("policy-table") {
            anyhow::ensure!(
                policy == Policy::Auto,
                "--policy-table only applies to --policy auto (got --policy {})",
                policy.name()
            );
            cfg.policy_table = Some(psl::fleet::PolicyTable::load(table_path)?);
        }
        FleetSession::new(cfg)
    };

    let out_name = args.str_of("out", "fleet");
    let dir = std::path::Path::new("target/psl-bench");
    std::fs::create_dir_all(dir)?;
    let stream = session.event_stream();
    let rounds = stream.len();
    let start = session.next_round();
    if start >= 1 {
        // A resumed session must continue the stream its config
        // regenerates; a serve checkpoint driven by external events has a
        // different membership history and must go back through serve.
        anyhow::ensure!(
            stream[start - 1].roster == session.roster(),
            "checkpoint roster does not match the generated event stream at round {} — \
             this checkpoint was driven by external events; resume it with `psl serve --resume`",
            start - 1
        );
    }

    // Event-log sidecar: the full membership stream, in the exact line
    // format `psl serve` consumes on stdin.
    let events_path = dir.join(format!("{out_name}.events.jsonl"));
    let events_text: String = stream.iter().map(|ev| ev.jsonl_line() + "\n").collect();
    let events_err = std::fs::write(&events_path, &events_text).err();
    if let Some(e) = &events_err {
        eprintln!("warning: events log {} not written: {e}", events_path.display());
    }

    // Stream each finished round as a JSONL line next to the final JSON,
    // so long-horizon runs leave a usable trace even if interrupted. A
    // resumed run replays its completed prefix first, so the sidecar is
    // complete either way.
    let jsonl_path = dir.join(format!("{out_name}.rounds.jsonl"));
    let jsonl_file = std::fs::File::create(&jsonl_path)
        .with_context(|| format!("create {}", jsonl_path.display()))?;
    let mut writer = std::io::BufWriter::new(jsonl_file);
    let mut io_err: Option<std::io::Error> = None;
    let mut sink = |round: &psl::fleet::RoundReport| {
        use std::io::Write;
        if io_err.is_none() {
            let res = writeln!(writer, "{}", round.jsonl_line()).and_then(|_| writer.flush());
            if let Err(e) = res {
                io_err = Some(e);
            }
        }
    };
    for r in session.completed() {
        sink(r);
    }
    let ckpt_name = format!("{out_name}.ckpt");
    for ev in &stream[start..] {
        let round = session.step(ev);
        sink(&round);
        if let Some(every) = checkpoint_every {
            // Unlike the sidecars, a failed snapshot defeats the point of
            // checkpointing — fail the run.
            if session.next_round() % every == 0 {
                let path = session
                    .checkpoint()
                    .save(&ckpt_name)
                    .with_context(|| format!("save checkpoint after round {}", round.round))?;
                println!("checkpoint -> {}", path.display());
            }
        }
    }
    drop(sink);
    // The sidecar is a convenience trace: a write failure must not throw
    // away the completed run — warn and still save the final report.
    if let Some(e) = &io_err {
        eprintln!("warning: rounds stream {} truncated: {e}", jsonl_path.display());
    }
    let report = session.into_report();
    println!("{} | policy {} | slot {} ms | {} rounds", report.label, report.policy, report.slot_ms, rounds);
    println!(
        "  {:>5} {:>3} {:>4} {:>4} {:>4} {:>4} {:<15} {:<8} {:>8} {:>12} {:>11} {:>6} {:>10}",
        "round", "J", "arr", "dep", "live", "orph", "decision", "method", "slots", "makespan[s]", "period[s]", "moves", "work"
    );
    for r in &report.rounds {
        println!(
            "  {:>5} {:>3} {:>4} {:>4} {:>4} {:>4} {:<15} {:<8} {:>8} {:>12.1} {:>11.1} {:>6} {:>10}",
            r.round,
            r.n_clients,
            r.arrivals,
            r.departures,
            r.helpers_live,
            r.orphaned_clients,
            r.decision,
            r.method.unwrap_or("-"),
            r.makespan_slots,
            r.makespan_ms / 1000.0,
            r.period_ms / 1000.0,
            r.repair_moves,
            r.work_units
        );
    }
    println!(
        "summary: {} full / {} repair / {} empty | {} degraded, {} migrations | mean makespan {:.1} s | mean period {:.1} s | total work {}",
        report.full_rounds(),
        report.repair_rounds(),
        report.empty_rounds(),
        report.degraded_rounds(),
        report.total_migrations(),
        report.mean_makespan_ms() / 1000.0,
        report.mean_period_ms() / 1000.0,
        report.total_work_units()
    );
    let path = report.save(&out_name)?;
    println!("report -> {}", path.display());
    if io_err.is_none() {
        println!("rounds stream -> {}", jsonl_path.display());
    }
    if events_err.is_none() {
        println!("events log -> {}", events_path.display());
    }
    Ok(())
}

/// `psl serve`: the orchestrator as a long-lived decision service.
/// [`RoundEvents`](psl::fleet::RoundEvents) JSONL on stdin (the
/// `.events.jsonl` sidecar line format), one
/// [`RoundReport`](psl::fleet::RoundReport) JSONL line per event on
/// stdout, flushed per round. A `{"checkpoint": "name"}` control line —
/// or `--checkpoint-every N` — snapshots the session as a resumable
/// `psl-fleet-checkpoint` artifact. Diagnostics go to stderr, so stdout
/// stays a pure report stream (diffable against a batch run's
/// `.rounds.jsonl`).
fn cmd_serve(args: &Args) -> Result<()> {
    use psl::fleet::{serve, ChurnCfg, FleetCfg, FleetCheckpoint, FleetSession, Policy, ServeOpts};
    let out_name = args.str_of("out", "serve");
    let mut session = if let Some(ckpt_path) = args.flags.get("resume") {
        reject_recorded_flags(args)?;
        FleetSession::resume(FleetCheckpoint::load(ckpt_path)?)?
    } else {
        let scenario = Scenario::parse(&args.str_of("scenario", "4")).context("bad --scenario")?;
        let model = Model::parse(&args.str_of("model", "resnet101")).context("bad --model")?;
        let j = args.usize_of("j", 10);
        let i = args.usize_of("i", 2);
        anyhow::ensure!(j >= 1 && i >= 1, "serve needs -j >= 1 and -i >= 1");
        let policy = Policy::parse(&args.str_of("policy", "incremental"))
            .context("bad --policy (incremental|full|repair-only|auto)")?;
        let max_clients: usize = parsed_flag(args, "max-clients", (2 * j).max(1))?;
        // Events arrive on stdin, so the churn-process knobs are moot;
        // the cap still sizes the world's wedge-free memory repair (and
        // matches `psl fleet`'s default, so serve over a recorded
        // `.events.jsonl` reproduces the batch run's reports exactly).
        let churn = ChurnCfg { rounds: 1, arrival_rate: 0.0, departure_prob: 0.0, max_clients };
        let scen = psl::instance::scenario::ScenarioCfg::new(scenario, model, j, i, args.u64_of("seed", 42));
        let mut cfg = FleetCfg::new(scen, churn, policy);
        cfg.slot_ms = match args.flags.get("slot-ms") {
            None => None,
            Some(v) => {
                let ms: f64 = v.parse().ok().with_context(|| format!("bad --slot-ms {v:?}"))?;
                anyhow::ensure!(ms > 0.0, "--slot-ms must be positive, got {ms}");
                Some(ms)
            }
        };
        cfg.churn_threshold = parsed_flag(args, "churn-threshold", cfg.churn_threshold)?;
        cfg.gap_threshold = parsed_flag(args, "gap-threshold", cfg.gap_threshold)?;
        cfg.epoch_batches = parsed_flag(args, "batches", cfg.epoch_batches)?;
        apply_helper_flags(args, &mut cfg)?;
        cfg.transport = parse_transport_flags(args)?;
        if let Some(table_path) = args.flags.get("policy-table") {
            anyhow::ensure!(
                policy == Policy::Auto,
                "--policy-table only applies to --policy auto (got --policy {})",
                policy.name()
            );
            cfg.policy_table = Some(psl::fleet::PolicyTable::load(table_path)?);
        }
        FleetSession::new(cfg)
    };
    let opts = ServeOpts {
        checkpoint_every: optional_count_flag(args, "checkpoint-every")?,
        checkpoint_name: format!("{out_name}.ckpt"),
        strict: args.bool_of("strict"),
    };
    let cfg = session.cfg();
    eprintln!(
        "serve: fleet:{}/{} J={} I={} seed={} | policy {} | round {} | roster cap {} — events on stdin, reports on stdout",
        cfg.scenario.spec.name,
        cfg.scenario.model.name(),
        cfg.scenario.n_clients,
        cfg.scenario.n_helpers,
        cfg.scenario.seed,
        cfg.policy.name(),
        session.next_round(),
        session.max_clients()
    );
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let summary = serve(&mut session, stdin.lock(), stdout.lock(), &opts)?;
    eprintln!(
        "serve: {} rounds stepped, {} checkpoints, {} errored lines (cursor at round {})",
        summary.rounds,
        summary.checkpoints,
        summary.errors,
        session.next_round()
    );
    Ok(())
}

/// `psl perf`: time the solve/check/replay hot paths across scenario
/// families and sizes, compare against the dense-representation
/// baselines, and append a point to the perf trajectory
/// (`target/psl-bench/<out>.json`). Non-zero exit on non-finite timings
/// or dense/run replay divergence.
fn cmd_perf(args: &Args) -> Result<()> {
    use psl::bench::perf;
    anyhow::ensure!(
        !(args.bool_of("smoke") && args.bool_of("full")),
        "--smoke and --full are mutually exclusive"
    );
    // perf captures solver counters through its own per-cell Recording
    // (which holds the process-wide recording lock), so an outer --trace
    // recording would deadlock; the counters land in the psl-perf rows.
    anyhow::ensure!(
        !args.flags.contains_key("trace"),
        "psl perf records solver counters internally (see the psl-perf rows) and takes no --trace"
    );
    let mut cfg = if args.bool_of("smoke") {
        perf::PerfCfg::smoke()
    } else if args.bool_of("full") {
        perf::PerfCfg::full()
    } else {
        perf::PerfCfg::default()
    };
    if args.flags.contains_key("scenarios") {
        cfg.scenarios = csv_list(args, "scenarios", "")
            .iter()
            .map(|s| Scenario::parse(s).with_context(|| format!("bad scenario {s:?} in --scenarios")))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!cfg.scenarios.is_empty(), "--scenarios must name at least one family");
    }
    if args.flags.contains_key("sizes") {
        cfg.sizes = csv_list(args, "sizes", "")
            .iter()
            .map(|s| {
                let (j, i) = s.split_once('x').with_context(|| format!("size {s:?} is not JxI"))?;
                let j = j.trim().parse::<usize>().ok().with_context(|| format!("bad J in {s:?}"))?;
                let i = i.trim().parse::<usize>().ok().with_context(|| format!("bad I in {s:?}"))?;
                anyhow::ensure!(j >= 1 && i >= 1, "size {s:?} needs J >= 1 and I >= 1");
                Ok((j, i))
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!cfg.sizes.is_empty(), "--sizes must name at least one JxI cell");
    }
    cfg.model = Model::parse(&args.str_of("model", cfg.model.name())).context("bad --model")?;
    cfg.seed = parsed_flag(args, "seed", cfg.seed)?;
    cfg.iters = parsed_flag(args, "iters", cfg.iters)?;
    anyhow::ensure!(cfg.iters >= 1, "--iters must be >= 1");

    println!(
        "perf: {} scenarios x {} sizes, {} timed iters (model {})",
        cfg.scenarios.len(),
        cfg.sizes.len(),
        cfg.iters,
        cfg.model.name()
    );
    let rows = perf::run(&cfg);
    perf::validate(&rows).context("perf timings failed validation")?;
    println!(
        "  {:<20} {:>5} {:>3} {:<13} {:>10} {:>10} {:>8} {:>9} {:>10}",
        "scenario", "J", "I", "phase", "mean", "p50", "slots", "runs", "makespan"
    );
    for r in &rows {
        println!(
            "  {:<20} {:>5} {:>3} {:<13} {:>10} {:>10} {:>8} {:>9} {:>10}",
            r.scenario,
            r.n_clients,
            r.n_helpers,
            r.phase,
            psl::bench::fmt_s(r.mean_s),
            psl::bench::fmt_s(r.p50_s),
            r.total_slots,
            r.total_runs,
            r.makespan_slots
        );
    }
    // Headline: run-length vs dense on the hot read paths, per cell.
    let mean_of = |scenario: &str, j: usize, i: usize, phase: &str| -> Option<f64> {
        rows.iter()
            .find(|r| r.scenario == scenario && r.n_clients == j && r.n_helpers == i && r.phase == phase)
            .map(|r| r.mean_s)
    };
    for &scen in &cfg.scenarios {
        for &(j, i) in &cfg.sizes {
            let (Some(c), Some(cd), Some(rp), Some(rpd)) = (
                mean_of(scen.name(), j, i, "check"),
                mean_of(scen.name(), j, i, "check-dense"),
                mean_of(scen.name(), j, i, "replay"),
                mean_of(scen.name(), j, i, "replay-dense"),
            ) else {
                continue;
            };
            let speedup = |dense: f64, runs: f64| -> String {
                if runs > 0.0 { format!("{:.1}x", dense / runs) } else { "-".into() }
            };
            println!(
                "  {}/{}x{}: check {} vs dense {} ({}) | replay {} vs dense {} ({})",
                scen.name(),
                j,
                i,
                psl::bench::fmt_s(c),
                psl::bench::fmt_s(cd),
                speedup(cd, c),
                psl::bench::fmt_s(rp),
                psl::bench::fmt_s(rpd),
                speedup(rpd, rp)
            );
        }
    }
    let path = perf::save(&rows, &args.str_of("out", "perf"))?;
    println!("{} rows -> {}", rows.len(), path.display());
    Ok(())
}

/// `psl shard`: the sharded hierarchical solver as a grid runner —
/// partition each scenario × size cell into helper cells, solve the
/// cells concurrently over the worker pool, stitch the per-shard
/// schedules into one global schedule and save the deterministic
/// `psl-shard` artifact (per-shard makespans, stitched makespan,
/// stitch gap vs. the per-shard and monolithic lower bounds).
fn cmd_shard(args: &Args) -> Result<()> {
    use psl::shard::{grid, ShardCfg, ShardGridCfg};
    let scenarios = csv_list(args, "scenarios", "6")
        .iter()
        .map(|s| Scenario::parse(s).with_context(|| format!("bad scenario {s:?} in --scenarios")))
        .collect::<Result<Vec<_>>>()?;
    let sizes = csv_list(args, "sizes", "8192x64")
        .iter()
        .map(|s| {
            let (j, i) = s.split_once('x').with_context(|| format!("size {s:?} is not JxI"))?;
            let j = j.trim().parse::<usize>().ok().with_context(|| format!("bad J in {s:?}"))?;
            let i = i.trim().parse::<usize>().ok().with_context(|| format!("bad I in {s:?}"))?;
            anyhow::ensure!(j >= 1 && i >= 1, "size {s:?} needs J >= 1 and I >= 1");
            Ok((j, i))
        })
        .collect::<Result<Vec<_>>>()?;
    let slot_ms = match args.flags.get("slot-ms") {
        None => None,
        Some(v) => {
            let ms: f64 = v.parse().ok().with_context(|| format!("bad --slot-ms {v:?}"))?;
            anyhow::ensure!(ms > 0.0, "--slot-ms must be positive, got {ms}");
            Some(ms)
        }
    };
    let mut shard = ShardCfg::default();
    shard.shard_clients = parsed_flag(args, "shard-clients", shard.shard_clients)?;
    anyhow::ensure!(shard.shard_clients >= 1, "--shard-clients must be >= 1");
    shard.rebalance_gap = parsed_flag(args, "rebalance-gap", shard.rebalance_gap)?;
    anyhow::ensure!(
        shard.rebalance_gap >= 1.0 && shard.rebalance_gap.is_finite(),
        "--rebalance-gap must be >= 1, got {}",
        shard.rebalance_gap
    );
    shard.max_migrations = parsed_flag(args, "max-migrations", shard.max_migrations)?;
    let cfg = ShardGridCfg {
        scenarios,
        model: Model::parse(&args.str_of("model", "resnet101")).context("bad --model")?,
        sizes,
        seed: args.u64_of("seed", 42),
        slot_ms,
        shard,
        threads: args.usize_of("threads", psl::exec::pool::default_workers()),
    };
    println!(
        "shard: {} scenarios x {} sizes | target {} clients/cell | rebalance gap {} | <= {} migrations | {} threads",
        cfg.scenarios.len(),
        cfg.sizes.len(),
        cfg.shard.shard_clients,
        cfg.shard.rebalance_gap,
        cfg.shard.max_migrations,
        cfg.threads
    );
    let start = std::time::Instant::now();
    let rows = grid::run(&cfg)?;
    let wall = start.elapsed().as_secs_f64();
    for r in &rows {
        println!(
            "  {} {}x{} (seed {}): {} shards, {} migrations -> stitched {} slots ({:.1} s) | stitch gap {:.3} | mono lb {} slots",
            r.scenario.name(),
            r.n_clients,
            r.n_helpers,
            r.seed,
            r.n_shards,
            r.migrations,
            r.stitched_makespan_slots,
            r.stitched_makespan_ms / 1000.0,
            r.stitch_gap,
            r.monolithic_lb_slots
        );
        for s in &r.shards {
            println!(
                "    shard {:>2} [helper {:>4}+]: {:>5} clients x {:>3} helpers | {:<8} | makespan {:>6} | lb {:>6}",
                s.shard, s.min_helper, s.n_clients, s.n_helpers, s.method.name(), s.makespan_slots, s.lower_bound_slots
            );
        }
    }
    let path = grid::save(&args.str_of("out", "shard"), &rows)?;
    println!("{} rows -> {} in {} ({} threads)", rows.len(), path.display(), psl::bench::fmt_s(wall), cfg.threads);
    Ok(())
}

/// `psl analyze`: consume `target/psl-bench` artifacts. Two modes:
/// default — load a fleet-grid artifact, print the per-(family × size)
/// regime tables, compute the churn-rate policy frontier and save it as
/// a `PolicyTable` artifact (`--out`, default `policy-table`);
/// `--perf-diff OLD NEW` — gate two perf-trajectory points against each
/// other (non-zero exit on solve/check/replay slowdowns or solver-counter
/// blowups beyond `--tol`); `--rounds` / `--shard` / `--trace` — summary
/// tables for the respective sidecar / artifact kinds.
fn cmd_analyze(args: &Args) -> Result<()> {
    if let Some(old_path) = args.flags.get("perf-diff") {
        return cmd_perf_diff(args, old_path);
    }
    if let Some(path) = args.flags.get("rounds") {
        return cmd_rounds_summary(path);
    }
    if let Some(path) = args.flags.get("shard") {
        return cmd_shard_summary(path);
    }
    if let Some(path) = args.flags.get("trace") {
        return cmd_trace_summary(path);
    }
    let grid_path = args.positional.first().context(
        "usage: psl analyze <fleet-grid.json> [--out NAME]\n       psl analyze --perf-diff <old.json> <new.json> [--tol X]\n       psl analyze --rounds <file.rounds.jsonl>\n       psl analyze --shard <shard.json>\n       psl analyze --trace <trace.json>",
    )?;
    let doc = psl::bench::artifact::load_expecting(grid_path, psl::bench::ArtifactKind::FleetGrid)?;
    let rows = psl::analyze::rows_from_doc(&doc)?;
    let tables = psl::analyze::regime_tables(&rows);
    println!("analyze: {} grid rows -> {} regime tables", rows.len(), tables.len());
    // Regime axes beyond scenario/size print only when non-default, so
    // plain grids keep their historical header lines.
    let axes = |helper_down_rate: f64, uplink_capacity: f64| {
        let mut s = String::new();
        if helper_down_rate > 0.0 {
            s.push_str(&format!(" h-down={helper_down_rate:.2}"));
        }
        if uplink_capacity > 0.0 {
            s.push_str(&format!(" uplink-cap={uplink_capacity}"));
        }
        s
    };
    for t in &tables {
        println!(
            "  {} {}x{}{}:",
            t.scenario,
            t.n_clients,
            t.n_helpers,
            axes(t.helper_down_rate, t.uplink_capacity)
        );
        println!(
            "    {:>6} {:>9} {:<12} {:>5} {:>13} {:>12} {:>14}",
            "churn", "obs-churn", "policy", "seeds", "makespan[s]", "work", "score"
        );
        for c in &t.cells {
            println!(
                "    {:>6.2} {:>9.2} {:<12} {:>5} {:>13.1} {:>12.0} {:>14.3e}",
                c.churn_rate,
                c.mean_churn_frac,
                c.policy,
                c.seeds,
                c.mean_makespan_ms / 1000.0,
                c.mean_work_units,
                c.score
            );
        }
    }
    let frontiers = psl::analyze::frontiers(&tables);
    anyhow::ensure!(
        !frontiers.is_empty(),
        "no (incremental, full) pair at any churn rate in {grid_path} — run the grid with --policies incremental,full"
    );
    println!("policy frontier (full re-solving overtakes incremental repair at):");
    for f in &frontiers {
        let ax = axes(f.helper_down_rate, f.uplink_capacity);
        match f.crossover {
            Some(frac) => println!(
                "  {} {}x{}{}: observed churn >= {:.2}  ({} rates compared)",
                f.scenario, f.n_clients, f.n_helpers, ax, frac, f.rates_compared
            ),
            None => println!(
                "  {} {}x{}{}: incremental wins at every measured rate ({} compared)",
                f.scenario, f.n_clients, f.n_helpers, ax, f.rates_compared
            ),
        }
    }
    // Provenance label: the artifact filename without its directory.
    let source = std::path::Path::new(grid_path)
        .file_name()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| grid_path.to_string());
    let table = psl::analyze::compute_policy_table(frontiers, &source);
    let path = table.save(&args.str_of("out", "policy-table"))?;
    println!(
        "{} policy-table entries -> {} (use with: psl fleet --policy auto --policy-table {})",
        table.entries.len(),
        path.display(),
        path.display()
    );
    Ok(())
}

/// `psl analyze --rounds <file.rounds.jsonl>`: per-decision summary of a
/// fleet run's streamed round sidecar — what the orchestrator decided,
/// how often, at what observed churn, and what it cost.
fn cmd_rounds_summary(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
    let rows = psl::analyze::rounds::rows_from_jsonl(&text)?;
    anyhow::ensure!(!rows.is_empty(), "{path} contains no rounds");
    println!("rounds: {} streamed from {path}", rows.len());
    println!(
        "  {:<15} {:>6} {:>10} {:>14} {:>12} {:>12} {:>5} {:>5} {:>6} {:>9}",
        "decision", "rounds", "mean-churn", "makespan[s]", "period[s]", "work", "degr", "orph", "admm-y", "mean-cont"
    );
    for s in psl::analyze::rounds::summarize(&rows) {
        println!(
            "  {:<15} {:>6} {:>10.2} {:>14.1} {:>12.1} {:>12} {:>5} {:>5} {:>6} {:>9.2}",
            s.decision,
            s.rounds,
            s.mean_churn_frac,
            s.mean_makespan_ms / 1000.0,
            s.mean_period_ms / 1000.0,
            s.total_work_units,
            s.degraded_rounds,
            s.orphaned_clients,
            s.admm_y_repairs,
            s.mean_contention
        );
    }
    Ok(())
}

/// `psl analyze --shard <shard.json>`: per-cell summary of a `psl-shard`
/// artifact — where the stitched solve sits against its per-shard and
/// monolithic lower bounds, how much rebalancing fired, and which
/// methods the shards picked.
fn cmd_shard_summary(path: &str) -> Result<()> {
    let doc = psl::bench::artifact::load_expecting(path, psl::bench::ArtifactKind::Shard)?;
    let rows = psl::analyze::summaries_from_doc(&doc)?;
    anyhow::ensure!(!rows.is_empty(), "{path} contains no shard rows");
    println!("shard: {} cells from {path}", rows.len());
    print!("{}", psl::analyze::shard::render_table(&rows));
    Ok(())
}

/// `psl analyze --trace <trace.json>`: reduce a `psl-trace` capture to
/// its per-phase duration table (wall-clock, non-deterministic) and its
/// deterministic counter table.
fn cmd_trace_summary(path: &str) -> Result<()> {
    let s = psl::analyze::summarize_file(path)?;
    anyhow::ensure!(
        !s.phases.is_empty() || !s.counters.is_empty(),
        "{path} recorded no spans or counters"
    );
    println!("trace: {path}");
    print!("{}", psl::analyze::trace::render(&s));
    Ok(())
}

/// `psl analyze --perf-diff <old.json> <new.json>`: cell-by-cell timing
/// comparison of two perf artifacts; non-zero exit when a gated phase
/// (solve/check/replay) slowed beyond `--tol` (relative, default 25% —
/// timings are noisier than makespans) or a deterministic solver counter
/// (exact nodes, ADMM iterations) blew past the same tolerance.
fn cmd_perf_diff(args: &Args, old_path: &str) -> Result<()> {
    let new_path = args
        .positional
        .first()
        .context("usage: psl analyze --perf-diff <old.json> <new.json> [--tol X]")?;
    let tol: f64 = parsed_flag(args, "tol", 0.25)?;
    anyhow::ensure!(tol >= 0.0, "--tol must be non-negative, got {tol}");
    let load = |path: &str| -> Result<psl::util::json::Json> {
        Ok(psl::bench::artifact::load(path)?.1)
    };
    let report = psl::analyze::perfdiff::diff_documents(&load(old_path)?, &load(new_path)?, tol)?;
    // A gate that compared nothing must not pass green — zero overlap
    // means the two artifacts cover disjoint grids (e.g. a --smoke point
    // diffed against a --full point).
    anyhow::ensure!(
        report.compared > 0,
        "no gated perf cell appears in both {old_path} and {new_path} ({} only-old, {} only-new) — \
         are these the same perf grid?",
        report.only_old,
        report.only_new
    );
    println!(
        "perf diff: {} gated cells compared (tol {:.0}%) | {} improved | {} only-old | {} only-new",
        report.compared,
        tol * 100.0,
        report.improved,
        report.only_old,
        report.only_new
    );
    for r in &report.regressions {
        println!(
            "  REGRESSION {}: {} -> {}",
            r.cell,
            psl::bench::fmt_s(r.old_s),
            psl::bench::fmt_s(r.new_s)
        );
    }
    for r in &report.counter_regressions {
        println!("  COUNTER REGRESSION {} {}: {} -> {}", r.cell, r.counter, r.old, r.new);
    }
    if report.clean() {
        println!("no regressions");
        Ok(())
    } else {
        anyhow::bail!(
            "{} timing / {} counter regression(s) beyond {:.0}% tolerance",
            report.regressions.len(),
            report.counter_regressions.len(),
            tol * 100.0
        )
    }
}

/// `psl fleet --grid`: the scenario × churn-rate × policy grid over the
/// worker pool (thread-count-independent JSON like `psl sweep`).
fn cmd_fleet_grid(args: &Args) -> Result<()> {
    use psl::bench::fleet as grid;
    use psl::fleet::Policy;
    // Grid cells run the tested stationary defaults over the grid axes;
    // reject single-run knobs (including the singular --scenario/--seed
    // spellings) instead of silently ignoring them. (--policy-table is
    // shared with single runs: it feeds the grid's auto cells.)
    for key in [
        "policy",
        "depart-prob",
        "arrival-rate",
        "max-clients",
        "churn-threshold",
        "gap-threshold",
        "batches",
        "scenario",
        "seed",
        "helper-down-rate",
        "helper-outage-rounds",
        "helper-join-rate",
        "max-helpers",
        "diurnal-period",
        "capacity-threshold",
        "link-model",
        "uplink-capacity",
    ] {
        anyhow::ensure!(
            !args.flags.contains_key(key),
            "--{key} applies to single fleet runs, not --grid (grid axes: --scenarios/--churn-rates/--helper-down-rates/--uplink-capacities/--policies/--seeds)"
        );
    }
    let list = |key: &str, default: &str| csv_list(args, key, default);
    let scenarios = list("scenarios", "1,4")
        .iter()
        .map(|s| Scenario::parse(s).with_context(|| format!("bad scenario {s:?} in --scenarios")))
        .collect::<Result<Vec<_>>>()?;
    let model = Model::parse(&args.str_of("model", "resnet101")).context("bad --model")?;
    let churn_rates = list("churn-rates", "0.05,0.15,0.3")
        .iter()
        .map(|s| {
            let c: f64 = s.parse().ok().with_context(|| format!("bad churn rate {s:?}"))?;
            anyhow::ensure!((0.0..=1.0).contains(&c), "churn rate {c} outside [0, 1]");
            Ok(c)
        })
        .collect::<Result<Vec<_>>>()?;
    let helper_down_rates = list("helper-down-rates", "0")
        .iter()
        .map(|s| {
            let r: f64 = s.parse().ok().with_context(|| format!("bad helper down rate {s:?}"))?;
            anyhow::ensure!(
                r.is_finite() && (0.0..=1.0).contains(&r),
                "helper down rate {r} outside [0, 1]"
            );
            Ok(r)
        })
        .collect::<Result<Vec<_>>>()?;
    // The transport axis: 0 = dedicated links, > 0 = a shared uplink pool
    // of that capacity. Mirrors --helper-down-rates' shape so frontier
    // grids can sweep both failure and contention regimes at once.
    let uplink_capacities = list("uplink-capacities", "0")
        .iter()
        .map(|s| {
            let c: f64 = s.parse().ok().with_context(|| format!("bad uplink capacity {s:?}"))?;
            anyhow::ensure!(
                c.is_finite() && c >= 0.0,
                "uplink capacity {c} must be finite and >= 0 (0 = dedicated)"
            );
            Ok(c)
        })
        .collect::<Result<Vec<_>>>()?;
    let policies = list("policies", "incremental,full")
        .iter()
        .map(|s| Policy::parse(s).with_context(|| format!("bad policy {s:?} (incremental|full|repair-only|auto)")))
        .collect::<Result<Vec<_>>>()?;
    let policy_table = match args.flags.get("policy-table") {
        None => None,
        Some(path) => {
            anyhow::ensure!(
                policies.contains(&Policy::Auto),
                "--policy-table only applies when --policies includes auto"
            );
            Some(psl::fleet::PolicyTable::load(path)?)
        }
    };
    let seeds = list("seeds", "42")
        .iter()
        .map(|s| s.parse::<u64>().ok().with_context(|| format!("bad seed {s:?}")))
        .collect::<Result<Vec<_>>>()?;
    let j = args.usize_of("j", 10);
    let i = args.usize_of("i", 2);
    anyhow::ensure!(j >= 1 && i >= 1, "fleet grid needs -j >= 1 and -i >= 1");
    let rounds: usize = parsed_flag(args, "rounds", 8)?;
    anyhow::ensure!(rounds >= 1, "--rounds must be >= 1");
    let slot_ms = match args.flags.get("slot-ms") {
        None => None,
        Some(v) => {
            let ms: f64 = v.parse().ok().with_context(|| format!("bad --slot-ms {v:?}"))?;
            anyhow::ensure!(ms > 0.0, "--slot-ms must be positive, got {ms}");
            Some(ms)
        }
    };
    let cfg = grid::FleetGridCfg {
        scenarios,
        model,
        size: (j, i),
        churn_rates,
        helper_down_rates,
        uplink_capacities,
        policies,
        seeds,
        rounds,
        slot_ms,
        policy_table,
        threads: args.usize_of("threads", psl::exec::pool::default_workers()),
    };
    let n = grid::cells(&cfg).len();
    println!(
        "fleet grid: {} scenarios x {} churn rates x {} helper rates x {} uplink capacities x {} policies x {} seeds = {} cells on {} threads",
        cfg.scenarios.len(),
        cfg.churn_rates.len(),
        cfg.helper_down_rates.len(),
        cfg.uplink_capacities.len(),
        cfg.policies.len(),
        cfg.seeds.len(),
        n,
        cfg.threads
    );
    let rows = grid::run(&cfg);
    println!(
        "  {:<20} {:>6} {:>6} {:>6} {:<12} {:>6} {:>5} {:>7} {:>6} {:>13} {:>11} {:>12}",
        "scenario", "churn", "h-down", "uplink", "policy", "seed", "full", "repair", "empty", "makespan[s]", "period[s]", "work"
    );
    for r in &rows {
        println!(
            "  {:<20} {:>6.2} {:>6.2} {:>6} {:<12} {:>6} {:>5} {:>7} {:>6} {:>13.1} {:>11.1} {:>12}",
            r.scenario,
            r.churn_rate,
            r.helper_down_rate,
            if r.uplink_capacity > 0.0 { format!("{}", r.uplink_capacity) } else { "-".into() },
            r.policy,
            r.seed,
            r.full_rounds,
            r.repair_rounds,
            r.empty_rounds,
            r.mean_makespan_ms / 1000.0,
            r.mean_period_ms / 1000.0,
            r.total_work_units
        );
    }
    let path = grid::save(&rows, &args.str_of("out", "fleet-grid"))?;
    println!("{} rows -> {}", rows.len(), path.display());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let req = TrainRequest {
        arch: args.str_of("arch", "vgg_mini"),
        artifacts_dir: args.str_of("artifacts", "artifacts").into(),
        n_clients: args.usize_of("j", 4),
        n_helpers: args.usize_of("i", 2),
        seed: args.u64_of("seed", 7),
        train: TrainCfg {
            batches_per_round: args.usize_of("batches", 4),
            rounds: args.usize_of("rounds", 3),
            lr: args.f64_of("lr", 0.05) as f32,
            seed: args.u64_of("seed", 7),
        },
    };
    let outcome = psl::coordinator::run_training(&req)?;
    println!(
        "method={} makespan={} slots; {} steps in {:.1}s wall",
        outcome.method, outcome.makespan_slots, outcome.report.steps, outcome.report.wall_s
    );
    println!("loss curve:");
    for (k, l) in outcome.report.loss_curve.iter().enumerate() {
        println!("  step {:>3}: {:.4}", k + 1, l);
    }
    println!("measured helper task times (ms):");
    for (i, j, f, b) in &outcome.report.measured_ms {
        println!("  helper {i} / client {j}: fwd {f:.1}  bwd {b:.1}");
    }
    Ok(())
}
