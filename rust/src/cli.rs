//! Command-line interface (hand-rolled — no `clap` in this image).
//!
//! Subcommands:
//!   psl profiles                      print the testbed bank (Table I / Fig 5)
//!   psl gen   <scenario args>         generate an instance → JSON
//!   psl solve <scenario args> [...]   solve + report (all methods)
//!   psl train <fleet args>            end-to-end split training over PJRT
//!   psl sweep-slots <scenario args>   Fig-6-style slot-length sweep
//!   psl sweep <grid args>             multi-threaded scenario × solver grid
//!   psl sweep --diff <old> <new>      compare two sweep artifacts
//!   psl fleet <churn args>            multi-round churn orchestration
//!                                     (--checkpoint-every / --resume)
//!   psl serve <scenario args>         stdin/stdout round-decision service
//!   psl perf [--smoke|--full]         solve/check/replay perf trajectory
//!   psl shard <grid args>             sharded hierarchical solve grid
//!   psl analyze <grid.json>           regime tables + policy frontier
//!   psl analyze --perf-diff OLD NEW   perf trajectory + counter gate
//!   psl analyze --shard FILE          stitch-gap summary of a shard artifact
//!   psl analyze --trace FILE          phase/counter summary of a trace capture
//!
//! `solve`, `fleet`, `shard` and `serve` accept `--trace FILE`: record
//! spans + solver counters ([`crate::obs`]) and write a `psl-trace`
//! Chrome trace-event artifact without changing any decision output.
//!
//! Common scenario args: --scenario 1..7  --model resnet101|vgg19  -j N
//! -i N  --seed S  --slot-ms X. Run `psl help` for the full list.

use std::collections::HashMap;

/// Parsed arguments: flags (`--key value` / `-j value`) + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. Flags take exactly one value; `--flag` followed
    /// by another flag or end-of-args is treated as boolean "true".
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        if argv.is_empty() {
            return out;
        }
        out.cmd = argv[0].clone();
        let mut k = 1;
        while k < argv.len() {
            let a = &argv[k];
            if let Some(name) = a.strip_prefix("--").or_else(|| a.strip_prefix('-')) {
                let has_value = k + 1 < argv.len() && !argv[k + 1].starts_with('-');
                if has_value {
                    out.flags.insert(name.to_string(), argv[k + 1].clone());
                    k += 2;
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                    k += 1;
                }
            } else {
                out.positional.push(a.clone());
                k += 1;
            }
        }
        out
    }

    pub fn str_of(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_of(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_of(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_of(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_of(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }
}

pub const HELP: &str = "\
psl — workflow optimization for parallel split learning (INFOCOM'24 repro)

USAGE: psl <command> [flags]

COMMANDS
  profiles      Print the testbed profile bank (Table I) and per-part
                compute times (Fig 5).
  gen           Generate a scenario instance and print/save its JSON.
  solve         Solve an instance with one or all methods and report
                makespans, queuing delays and (optionally) a Gantt JSON.
  train         Run real end-to-end split training over PJRT artifacts,
                driven by an optimized schedule (needs `make artifacts`).
  sweep-slots   Quantize the same system at several slot lengths and
                compare nominal vs realized makespan (Fig 6 logic).
  sweep         Run the full scenario × solver grid across worker threads
                and save deterministic JSON under target/psl-bench/.
                With --diff OLD NEW: compare two sweep artifacts cell by
                cell and exit non-zero on makespan regressions.
  fleet         Run a seeded multi-round churn simulation: clients arrive
                and depart between rounds — and, when a helper model is
                enabled, helpers drop out and return; orphaned clients
                are migrated to survivors (helper-degraded) or the round
                falls back to a full re-solve on the reduced pool
                (helper-resolve). Deterministic JSON report under
                target/psl-bench/, plus a round-by-round JSONL stream
                (<out>.rounds.jsonl) written as rounds finish. With
                --grid: the scenario x churn-rate x policy grid across
                worker threads. --checkpoint-every N snapshots the
                session as a resumable psl-fleet-checkpoint artifact;
                --resume CKPT continues one to the byte-identical final
                report and sidecars.
  serve         Run the orchestrator as a decision service: RoundEvents
                JSONL on stdin (the .events.jsonl sidecar line format),
                one RoundReport JSONL line per event on stdout, flushed
                per round. {\"checkpoint\": \"name\"} control lines (or
                --checkpoint-every N) snapshot the session; --resume
                continues a checkpoint. Diagnostics on stderr only.
  perf          Time the solver/checker/replay hot paths across scenario
                families and sizes, compare the run-length schedule
                representation against the dense baseline, and write the
                perf-trajectory artifact target/psl-bench/perf.json.
                --full adds mega cells (8192x64, 65536x64) that route
                through the sharded hierarchical solver.
  shard         Partition mega-scale instances into helper cells, solve
                the cells concurrently, stitch the per-shard schedules
                into one global schedule and report the stitching gap.
                Writes a deterministic psl-shard artifact under
                target/psl-bench/ (thread-count independent).
  analyze       Consume target/psl-bench artifacts: aggregate a fleet
                grid into per-family regime tables, compute the
                churn-rate policy frontier (where full re-solving
                overtakes incremental repair) and save it as a
                psl-policy-table artifact for `fleet --policy auto`.
                With --perf-diff OLD NEW: compare two perf artifacts and
                exit non-zero on solve/check/replay slowdowns or
                solver-counter blowups (exact nodes, ADMM iterations).
                With --rounds FILE: per-decision summary of a fleet
                .rounds.jsonl sidecar. With --shard FILE: per-cell
                stitch-gap / migration summary of a psl-shard artifact.
                With --trace FILE: per-phase duration + counter summary
                of a psl-trace capture.
  help          This text.

TRACING (solve/fleet/shard/serve)
  --trace FILE          record spans + solver counters while the command
                        runs and write a psl-trace artifact (Chrome
                        trace-event JSON; open in Perfetto or
                        chrome://tracing). Decision artifacts stay
                        byte-identical with or without it. `serve`
                        prints the trace path on stderr to keep stdout
                        pure. `perf` captures counters internally and
                        takes no --trace.

SCENARIO FLAGS (gen/solve/sweep-slots)
  --scenario NAME       scenario family (see below)    [default 1]
  --model resnet101|vgg19                              [default resnet101]
  -j N                  number of clients              [default 10]
  -i N                  number of helpers              [default 2]
  --seed S              RNG seed                       [default 42]
  --slot-ms X           slot length |S_t| in ms        [default: model's]
  --switch-cost MS      per-preemption cost (§VI)      [default 0]

SCENARIO FAMILIES
  1|scenario1           paper §VII low heterogeneity
  2|scenario2           paper §VII high heterogeneity
  3|s3-clustered        clustered device tiers, cellular-like links
  4|s4-straggler-tail   heavy straggler tail + client churn
  5|s5-memory-starved   tight varied helper memory, random cuts
  6|s6-mega-homogeneous huge identical fleet, uniform links
  7|s7-helper-bursts    s4 clients + bursty helper outages (fleet/serve
                        model transient helper downtime by default here)
  8|s8-flash-crowd      s4 clients + periodic flash-crowd arrival spikes
                        (fleet/serve multiply the arrival rate 4x every
                        4th round by default here)

SWEEP FLAGS
  --scenarios LIST      comma list of families         [default 1,2,3,4]
  --models LIST         comma list of models           [default resnet101]
  --sizes LIST          comma list of JxI cells        [default 10x2,20x5]
  --seeds LIST          comma list of seeds            [default 42]
  --methods LIST        admm|greedy|baseline|strategy  [default admm,greedy]
  --slot-ms X           override every model's |S_t|
  --link-model M        dedicated|shared transfer links [default dedicated]
  --uplink-capacity C   shared-pool capacity (concurrent full-rate
                        transfers per helper; needs --link-model shared)
                        [default 4]
  --threads N           worker threads                 [default: all cores]
  --out NAME            output name under target/psl-bench [default sweep]
  --diff OLD NEW        diff two sweep JSONs instead of running a grid
  --tol X               relative regression tolerance  [default 0.02]

FLEET FLAGS (plus --scenario/--model/-j/-i/--seed/--slot-ms; scenario
defaults to s4-straggler-tail)
  --rounds N            training rounds                [default 8]
  --depart-prob P       per-client departure prob      [default 0.12]
  --arrival-rate R      expected arrivals per round    [default P*J]
  --max-clients N       roster-size cap                [default 2*J]
  --policy NAME         incremental|full|repair-only|auto [default incremental]
  --policy-table FILE   measured frontier table for --policy auto
                        (psl-policy-table artifact from `psl analyze`;
                        default: the builtin table)
  --churn-threshold F   full re-solve when membership delta > F  [0.35]
  --gap-threshold F     full re-solve when repair gap > F x last full [1.75]
  --batches B           batches for the epoch period metric      [8]
  --helper-down-rate P  per-round helper outage probability [0; s7: 0.12]
  --helper-outage-rounds K  rounds a downed helper stays out   [default 2]
  --helper-join-rate R  expected helper arrivals per round     [default 0]
                        (needs --max-helpers above the base count)
  --max-helpers N       helper-pool cap for joins              [default 0]
  --diurnal-period N    if > 0, nights (second half of each period)
                        double the outage rate                 [default 0]
  --capacity-threshold F  full re-solve on the reduced helper set when
                        live capacity fraction drops below F   [0.5]
  --link-model M        dedicated|shared transfer links    [default dedicated]
  --uplink-capacity C   shared-pool capacity per helper (needs
                        --link-model shared)               [default 4]
  --out NAME            output name under target/psl-bench [default fleet]
                        (also writes <out>.rounds.jsonl and
                        <out>.events.jsonl sidecars)
  --checkpoint-every N  snapshot the session every N rounds to
                        target/psl-bench/<out>.ckpt.json
  --resume CKPT         continue a psl-fleet-checkpoint file; the config
                        is taken from the checkpoint, so only --rounds
                        (same or longer horizon), --out and
                        --checkpoint-every may accompany it
  --grid                run the scenario x churn-rate x helper-down-rate
                        x policy grid (--scenarios, --churn-rates,
                        --helper-down-rates, --policies, --seeds,
                        --threads as in sweep; --out default fleet-grid;
                        --policy-table feeds auto cells when --policies
                        includes auto; other single-run knobs like
                        --policy/--helper-down-rate are rejected — cells
                        use stationary defaults)
  --helper-down-rates LIST  (--grid only) helper outage-rate axis
                        [default 0]; 0 keeps the scenario's own helper
                        model, > 0 overrides it with 2-round outages
  --uplink-capacities LIST  (--grid only) shared-uplink capacity axis
                        [default 0]; 0 runs the cell on dedicated links,
                        > 0 on a shared pool of that capacity — frontiers
                        are computed per transport regime and the policy
                        table records the axis

SERVE FLAGS (plus --scenario/--model/-j/-i/--seed/--slot-ms, the fleet
policy knobs --policy/--policy-table/--churn-threshold/--gap-threshold/
--batches, the helper knobs --helper-down-rate/--helper-outage-rounds/
--helper-join-rate/--max-helpers/--diurnal-period/--capacity-threshold
and the transport knobs --link-model/--uplink-capacity; scenario
defaults to s4-straggler-tail)
  --max-clients N       roster cap the world is sized for  [default 2*J]
  --checkpoint-every N  snapshot the session every N stepped rounds to
                        target/psl-bench/<out>.ckpt.json (ack on stderr)
  --resume CKPT         continue a psl-fleet-checkpoint file (config
                        comes from the checkpoint; recorded knobs are
                        rejected)
  --strict              exit non-zero on the first bad event line instead
                        of answering it with an {\"error\": ...} line and
                        continuing (the lenient default)
  --out NAME            checkpoint name stem               [default serve]

  Event lines: {\"arrivals\": [ids], \"departures\": [ids]} with optional
  \"round\" and \"roster\" consistency fields and, on helper-modeled
  worlds, optional \"helper_down\"/\"helper_up\"/\"helper_join\" id lists;
  round 0's implicit previous roster is the base population 0..J. A
  {\"checkpoint\": \"name\"} line snapshots instead of stepping and acks
  on stdout. Under the lenient default a bad line answers with
  {\"error\": ..., \"line\": N} on stdout and the stream keeps serving.

PERF FLAGS
  --scenarios LIST      comma list of families         [default 1,2,6]
  --sizes LIST          comma list of JxI cells        [default 32x4,256x16]
  --model NAME          resnet101|vgg19                [default resnet101]
  --seed S              RNG seed                       [default 42]
  --iters N             timed reps per phase           [default 3]
  --smoke               tiny CI grid (8x2, 1 rep)
  --full                extended grid: + ADMM-heavy heterogeneous cells
                        at 48x6, a 512x32 cell, and sharded mega cells
                        at 8192x64 and 65536x64
  --out NAME            output name under target/psl-bench [default perf]

SHARD FLAGS
  --scenarios LIST      comma list of families         [default 6]
  --model NAME          resnet101|vgg19                [default resnet101]
  --sizes LIST          comma list of JxI cells        [default 8192x64]
  --seed S              RNG seed                       [default 42]
  --slot-ms X           slot length |S_t| in ms        [default: model's]
  --shard-clients N     target clients per cell        [default 1024]
  --rebalance-gap X     rebalance when stitched/max-shard-lb > X [1.25]
  --max-migrations N    cross-shard client moves cap   [default 4]
  --threads N           worker threads                 [default: all cores]
  --out NAME            output name under target/psl-bench [default shard]

ANALYZE FLAGS
  <grid.json>           positional: a psl-fleet-grid artifact to analyze
  --out NAME            policy-table output name       [default policy-table]
  --perf-diff OLD NEW   diff two psl-perf artifacts instead
  --tol X               relative timing tolerance      [default 0.25]
  --rounds FILE         summarize a fleet .rounds.jsonl sidecar per
                        decision instead
  --shard FILE          summarize a psl-shard artifact (stitch gap,
                        migrations, shard spread) instead
  --trace FILE          summarize a psl-trace artifact (per-phase span
                        durations + deterministic counters) instead

SOLVE FLAGS
  --method admm|greedy|baseline|exact|strategy|all     [default all]
  --gantt FILE          write the winning schedule's Gantt JSON
  --replay              continuous-time replay of each schedule
  --out FILE            (gen) write instance JSON to FILE

TRAIN FLAGS
  --arch vgg_mini|resnet_mini                          [default vgg_mini]
  -j N / -i N           fleet size                     [default 4 / 2]
  --rounds N            FedAvg rounds                  [default 3]
  --batches N           batch updates per round        [default 4]
  --lr X                learning rate                  [default 0.05]
  --artifacts DIR       artifacts directory            [default artifacts]
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        // NOTE: boolean flags absorb a following bare word as their value,
        // so positionals must precede them (documented parser semantics).
        let a = Args::parse(&argv("solve pos1 --scenario 2 -j 15 --replay"));
        assert_eq!(a.cmd, "solve");
        assert_eq!(a.str_of("scenario", "1"), "2");
        assert_eq!(a.usize_of("j", 10), 15);
        assert!(a.bool_of("replay"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("gen"));
        assert_eq!(a.usize_of("i", 2), 2);
        assert_eq!(a.f64_of("slot-ms", 180.0), 180.0);
        assert!(!a.bool_of("replay"));
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(&[]);
        assert_eq!(a.cmd, "");
    }
}
