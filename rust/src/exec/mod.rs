//! Thread substrate: actor kit ([`actor`]) and worker pool ([`pool`]).
//! The image ships no async runtime, so the SL runtime's concurrency is
//! built on plain threads + channels.

pub mod actor;
pub mod pool;

pub use actor::{spawn, Actor, Mailbox, Request};
pub use pool::run_parallel;
