//! A small fixed-size worker pool for CPU-parallel solving (per-helper
//! subproblems are independent — Theorem 2's parallelization point).
//! On this 1-core image it degenerates gracefully to sequential execution.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` across up to `workers` threads; returns results in job
/// order. Each job is an independent closure.
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    let queue: Arc<Mutex<Vec<(usize, F)>>> = Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut handles = Vec::new();
    for w in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("psl-pool-{w}"))
                .spawn(move || loop {
                    let job = queue.lock().unwrap().pop();
                    match job {
                        Some((idx, f)) => {
                            let _ = tx.send((idx, f()));
                        }
                        None => break,
                    }
                })
                .expect("spawn pool worker"),
        );
    }
    drop(tx);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (idx, v) in rx {
        out[idx] = Some(v);
    }
    for h in handles {
        h.join().expect("pool worker panicked");
    }
    out.into_iter().map(|v| v.expect("missing pool result")).collect()
}

/// Default worker count: available parallelism (≥ 1).
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_job_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..20usize).map(|k| Box::new(move || k * k) as _).collect();
        let out = run_parallel(4, jobs);
        assert_eq!(out, (0..20usize).map(|k| k * k).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_sequential() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..5usize).map(|k| Box::new(move || k) as _).collect();
        assert_eq!(run_parallel(1, jobs), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<fn() -> u8> = vec![];
        assert!(run_parallel(4, jobs).is_empty());
    }
}
