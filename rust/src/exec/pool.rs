//! A small fixed-size worker pool for CPU-parallel solving (per-helper
//! subproblems are independent — Theorem 2's parallelization point).
//! On this 1-core image it degenerates gracefully to sequential execution.
//!
//! Nested use is oversubscription-guarded: a job already running on a
//! pool worker that calls [`run_parallel`] again gets the sequential
//! fast path, so layered parallelism (a shard grid over shard solves
//! over per-helper subproblems) multiplies to `workers`, not
//! `workers^depth`.

use std::cell::Cell;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

thread_local! {
    /// True on threads spawned by [`run_parallel`] — nested calls on such
    /// threads must not fan out again.
    static IN_POOL: Cell<bool> = Cell::new(false);
}

/// Run `jobs` across up to `workers` threads; returns results in job
/// order. Each job is an independent closure. When called from inside a
/// pool worker (nested parallelism), the jobs run sequentially on the
/// calling worker regardless of `workers` — the outer pool already owns
/// the machine's parallelism.
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    // Deterministic observability: invocation/job totals are independent
    // of how many workers actually run (counted before the branch).
    crate::obs::counter_add("pool.invocations", 1);
    crate::obs::counter_add("pool.jobs", n as u64);
    let workers = if IN_POOL.with(|f| f.get()) { 1 } else { workers.max(1).min(n) };
    if workers == 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    // Workers inherit the spawning thread's recording enrollment, so
    // spans/counters from pool jobs land in the active recording.
    let token = crate::obs::current_token();
    let queue: Arc<Mutex<Vec<(usize, F)>>> = Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut handles = Vec::new();
    for w in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("psl-pool-{w}"))
                .spawn(move || {
                    IN_POOL.with(|f| f.set(true));
                    crate::obs::adopt_token(token);
                    {
                        // Worker-utilization span: lifetime of this worker
                        // within the pool call, jobs-run annotated (the
                        // which-worker-ran-what split is wall-clock detail
                        // and deliberately stays out of the counter map).
                        let mut span = crate::obs::span("exec", "pool/worker");
                        let mut jobs_run = 0u64;
                        loop {
                            let job = queue.lock().unwrap().pop();
                            match job {
                                Some((idx, f)) => {
                                    let _ = tx.send((idx, f()));
                                    jobs_run += 1;
                                }
                                None => break,
                            }
                        }
                        span.arg("jobs", jobs_run);
                    }
                    crate::obs::flush_thread();
                })
                .expect("spawn pool worker"),
        );
    }
    drop(tx);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (idx, v) in rx {
        out[idx] = Some(v);
    }
    for h in handles {
        h.join().expect("pool worker panicked");
    }
    out.into_iter().map(|v| v.expect("missing pool result")).collect()
}

/// Default worker count: available parallelism (≥ 1).
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_job_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..20usize).map(|k| Box::new(move || k * k) as _).collect();
        let out = run_parallel(4, jobs);
        assert_eq!(out, (0..20usize).map(|k| k * k).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_sequential() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..5usize).map(|k| Box::new(move || k) as _).collect();
        assert_eq!(run_parallel(1, jobs), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<fn() -> u8> = vec![];
        assert!(run_parallel(4, jobs).is_empty());
    }

    #[test]
    fn nested_calls_run_sequentially_on_the_outer_worker() {
        // Each outer job asks for 8 more workers; the guard must keep all
        // of its inner jobs on the outer worker's own thread.
        let outer: Vec<Box<dyn FnOnce() -> bool + Send>> = (0..4usize)
            .map(|_| {
                Box::new(move || {
                    let me = thread::current().id();
                    let inner: Vec<Box<dyn FnOnce() -> thread::ThreadId + Send>> =
                        (0..6usize).map(|_| Box::new(|| thread::current().id()) as _).collect();
                    run_parallel(8, inner).into_iter().all(|id| id == me)
                }) as _
            })
            .collect();
        assert!(run_parallel(4, outer).into_iter().all(|ok| ok));
    }

    #[test]
    fn guard_clears_for_fresh_top_level_calls() {
        // The guard is a property of pool-spawned threads, not global
        // state: a top-level call after a nested one still fans out.
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..8usize).map(|k| Box::new(move || k + 1) as _).collect();
        assert_eq!(run_parallel(4, jobs), (1..=8usize).collect::<Vec<_>>());
    }
}
