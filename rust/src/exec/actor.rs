//! Minimal thread-actor kit (no tokio in this image): each actor owns a
//! mailbox (mpsc channel) and a worker thread; requests carry a reply
//! channel. Used by the SL runtime's threaded mode where each helper is an
//! independent actor processing part-2 tasks in schedule order.

use std::sync::mpsc;
use std::thread;

/// Handle to send messages into an actor.
pub struct Mailbox<M: Send + 'static> {
    tx: mpsc::Sender<M>,
}

impl<M: Send + 'static> Clone for Mailbox<M> {
    fn clone(&self) -> Self {
        Mailbox { tx: self.tx.clone() }
    }
}

impl<M: Send + 'static> Mailbox<M> {
    pub fn send(&self, msg: M) -> Result<(), mpsc::SendError<M>> {
        self.tx.send(msg)
    }
}

/// A running actor: mailbox + join handle. Dropping the last mailbox
/// closes the channel; `join` then returns the actor's final state.
pub struct Actor<M: Send + 'static, R> {
    pub mailbox: Mailbox<M>,
    handle: thread::JoinHandle<R>,
}

impl<M: Send + 'static, R> Actor<M, R> {
    /// Wait for the actor to drain its mailbox and stop. Call after all
    /// mailbox clones (including `self.mailbox`) are dropped.
    pub fn join(self) -> thread::Result<R> {
        drop(self.mailbox);
        self.handle.join()
    }
}

/// Spawn an actor: `f` receives the message stream and runs until the
/// channel closes, returning its final state.
pub fn spawn<M, R, F>(name: &str, f: F) -> Actor<M, R>
where
    M: Send + 'static,
    R: Send + 'static,
    F: FnOnce(mpsc::Receiver<M>) -> R + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let handle = thread::Builder::new()
        .name(name.to_string())
        .spawn(move || f(rx))
        .expect("spawn actor thread");
    Actor { mailbox: Mailbox { tx }, handle }
}

/// Request/reply convenience: a message carrying a oneshot reply channel.
pub struct Request<Q, A> {
    pub query: Q,
    pub reply: mpsc::Sender<A>,
}

impl<Q, A> Request<Q, A> {
    pub fn call(mailbox: &Mailbox<Request<Q, A>>, query: Q) -> Option<A>
    where
        Q: Send + 'static,
        A: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        mailbox.send(Request { query, reply: tx }).ok()?;
        rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actor_processes_in_order_and_returns_state() {
        let actor = spawn("adder", |rx: mpsc::Receiver<u32>| {
            let mut sum = 0u64;
            let mut order = Vec::new();
            for m in rx {
                sum += m as u64;
                order.push(m);
            }
            (sum, order)
        });
        for k in 0..100u32 {
            actor.mailbox.send(k).unwrap();
        }
        let (sum, order) = actor.join().unwrap();
        assert_eq!(sum, 4950);
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn request_reply() {
        let actor = spawn("echo", |rx: mpsc::Receiver<Request<u32, u32>>| {
            for req in rx {
                let _ = req.reply.send(req.query * 2);
            }
        });
        assert_eq!(Request::call(&actor.mailbox, 21), Some(42));
        assert_eq!(Request::call(&actor.mailbox, 0), Some(0));
        actor.join().unwrap();
    }

    #[test]
    fn multiple_senders() {
        let actor = spawn("count", |rx: mpsc::Receiver<u32>| rx.iter().count());
        let m2 = actor.mailbox.clone();
        let t = thread::spawn(move || {
            for _ in 0..50 {
                m2.send(1).unwrap();
            }
        });
        for _ in 0..50 {
            actor.mailbox.send(2).unwrap();
        }
        t.join().unwrap();
        assert_eq!(actor.join().unwrap(), 100);
    }
}
